//! Table-4-style demo: pretrain a classifier with FULL attention, then
//! serve it with clustered-25 / i-clustered-25 attention *without any
//! retraining* — the checkpoint transfers because all variants share the
//! flat parameter layout.
//!
//!     cargo run --release --example approximate_pretrained -- [task] [steps]
//!
//! task ∈ {sst2, mrpc, qnli, rte, squad}

use anyhow::Result;
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging,
                                     RunConfig};
use clustered_transformers::coordinator::{trainer, DataFeed, TrainOptions};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::checkpoint::Checkpoint;
use clustered_transformers::runtime::Runtime;

fn main() -> Result<()> {
    init_logging(true);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let task = args.first().cloned().unwrap_or_else(|| "qnli".to_string());
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);

    let rt = Runtime::open(find_repo_root().join("artifacts"))?;
    let model = format!("glue-{task}-full");
    let cfg = RunConfig::default();
    cfg.ensure_dirs()?;
    let ckpt_path = cfg.checkpoint_path(&model);

    // 1. pretrain with full attention (or reuse an existing checkpoint)
    let ckpt = if ckpt_path.exists() {
        println!("reusing checkpoint {}", ckpt_path.display());
        Checkpoint::load(&ckpt_path)?
    } else {
        println!("== pretraining {model} with full attention ==");
        let opts = TrainOptions {
            steps,
            eval_every: (steps / 6).max(25),
            patience: 0,
            eval_batches: 2,
            seed: 0,
            verbose: true,
        };
        let (ckpt, result) = trainer::train_model(&rt, &model, &opts)?;
        println!("pretrained in {:.1}s (best val {:.4})",
                 result.wall_seconds, result.best_val_loss);
        ckpt.save(&ckpt_path)?;
        ckpt
    };

    // 2. evaluate the SAME weights under each attention variant
    println!("\n== swapping attention at inference (no retraining) ==");
    let mut table = Table::new(
        &format!("glue-analog {task}: pretrained-full served with variant"),
        &["evaluate with", "metric", "value"],
    );
    for variant in ["full", "clustered-25", "i-clustered-25"] {
        let fwd = format!("glue-{task}-{variant}.forward");
        if rt.program(&fwd).is_err() {
            eprintln!("  (skip {fwd}: not lowered)");
            continue;
        }
        let prog = rt.program(&fwd)?.clone();
        let feed = DataFeed::for_program(&prog, 0)?;
        let evals = trainer::forward_eval(&rt, &fwd, &ckpt.params, &feed,
                                          Split::Test, 8, 0)?;
        let score = trainer::score(&prog, &feed, &evals)?;
        table.row(vec![variant.to_string(), score.metric.to_string(),
                       format!("{:.4}", score.value)]);
    }
    table.emit();
    println!("expected shape (paper Table 4): i-clustered-25 ≈ full; plain \
              clustered-25 degrades on sparse-attention tasks.");
    Ok(())
}
