//! Quickstart: load an AOT artifact, run clustered attention end-to-end,
//! and compare the variants' outputs + costs on one real batch.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use clustered_transformers::attention::{self, Variant};
use clustered_transformers::benchlib;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::coordinator::DataFeed;
use clustered_transformers::data::Split;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::runtime::{HostTensor, Runtime};
use clustered_transformers::tensor::Matrix;

fn main() -> Result<()> {
    init_logging(false);
    let rt = Runtime::open(find_repo_root().join("artifacts"))?;
    println!("== quickstart: Fast Transformers with Clustered Attention ==");
    println!("manifest has {} programs\n", rt.program_names().len());

    // ------------------------------------------------------------------
    // 1. run a compiled transformer forward pass (i-clustered attention)
    // ------------------------------------------------------------------
    let name = "copy-n64-i-clustered-8.forward";
    let exe = rt.load(name)?;
    let p = exe.program.clone();
    let feed = DataFeed::for_program(&p, 0)?;
    let init = rt.load("copy-n64-i-clustered-8.init")?;
    let params = init.run(&[HostTensor::scalar_i32(0)])?.remove(0);

    let mut inputs = vec![params];
    inputs.extend(feed.forward_inputs(Split::Test, 0, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(0));
    let t0 = std::time::Instant::now();
    let out = exe.run(&inputs)?;
    println!(
        "ran {name}\n  batch {} × seq {} -> logits of {} floats in {}\n",
        p.batch_size(), p.seq_len(), out[0].len(),
        benchlib::fmt_time(t0.elapsed().as_secs_f64())
    );

    // ------------------------------------------------------------------
    // 2. the attention variants head-to-head on one head (native Rust)
    // ------------------------------------------------------------------
    let n = 2048;
    let dk = 64;
    let mut rng = Xoshiro256::new(0);
    let q = Matrix::randn(n, dk, &mut rng);
    let k = Matrix::randn(n, dk, &mut rng);
    let v = Matrix::randn(n, dk, &mut rng);

    let variants = [
        Variant::Full,
        Variant::Clustered { clusters: 100, bits: 63, iters: 10 },
        Variant::ImprovedClustered { clusters: 100, bits: 63, iters: 10,
                                     topk: 32 },
        Variant::Lsh { rounds: 1, chunk: 32 },
    ];
    let full_out = attention::full_attention(&q, &k, &v);
    let mut table = benchlib::Table::new(
        &format!("attention variants, single head, N={n}, Dk={dk}"),
        &["variant", "time", "flops (model)", "max|Δ| vs full"],
    );
    let ctx = clustered_transformers::exec::ExecCtx::sequential();
    for var in &variants {
        let p = attention::AttnProblem::new(&q, &k, &v);
        let mut r = Xoshiro256::new(1);
        let out = attention::solve(var, &p, &mut r, &ctx);
        let mut r2 = Xoshiro256::new(1);
        let st = benchlib::quick(|| {
            let _ = attention::solve(var, &p, &mut r2, &ctx);
        });
        let cost = attention::cost_model(var, n, dk, dk);
        table.row(vec![
            var.name(),
            benchlib::fmt_time(st.mean_s),
            format!("{:.2}G", cost.flops as f64 / 1e9),
            format!("{:.3}", out.max_abs_diff(&full_out)),
        ]);
    }
    table.emit();
    println!("note: i-clustered approximates full closely at a fraction of \
              the cost;\nplain clustered is cheapest but coarser — exactly \
              the paper's §3 story.");
    Ok(())
}
