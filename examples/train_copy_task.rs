//! End-to-end training driver (the repo's E2E validation run).
//!
//! Trains the masked-copy-task transformer with i-clustered attention for
//! a few hundred steps *through the compiled HLO train step* (Python never
//! runs), logs the loss curve, evaluates masked-token accuracy with the
//! forward artifact, and saves a checkpoint.  Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example train_copy_task -- [steps] [model]

use anyhow::Result;
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging,
                                     RunConfig};
use clustered_transformers::coordinator::{trainer, DataFeed, TrainOptions};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::Runtime;

fn main() -> Result<()> {
    init_logging(true);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "copy-n64-i-clustered-8".to_string());

    let rt = Runtime::open(find_repo_root().join("artifacts"))?;
    println!("== end-to-end training: {model} for {steps} steps ==");

    let opts = TrainOptions {
        steps,
        eval_every: (steps / 8).max(25),
        patience: 0,
        eval_batches: 2,
        seed: 0,
        verbose: true,
    };
    let (ckpt, result) = trainer::train_model(&rt, &model, &opts)?;

    // loss curve
    let mut curve = Table::new(&format!("{model} loss curve"),
                               &["step", "train loss"]);
    let stride = (result.losses.len() / 12).max(1);
    for (s, l) in result.losses.iter().step_by(stride) {
        curve.row(vec![format!("{s}"), format!("{l:.4}")]);
    }
    curve.emit();

    // accuracy with the matching forward program
    let fwd = format!("{model}.forward");
    let prog = rt.program(&fwd)?.clone();
    let feed = DataFeed::for_program(&prog, 0)?;
    let evals = trainer::forward_eval(&rt, &fwd, &ckpt.params, &feed,
                                      Split::Test, 8, 0)?;
    let score = trainer::score(&prog, &feed, &evals)?;

    println!(
        "\nsummary: {} steps in {:.1}s ({:.3}s/step) | final train loss \
         {:.4} | best val loss {:.4} | test {score}",
        result.steps_run, result.wall_seconds, result.seconds_per_step,
        result.final_loss, result.best_val_loss
    );

    let cfg = RunConfig::default();
    cfg.ensure_dirs()?;
    let path = cfg.checkpoint_path(&model);
    ckpt.save(&path)?;
    println!("checkpoint saved to {}", path.display());

    anyhow::ensure!(result.final_loss < result.losses[0].1,
                    "training failed to reduce the loss");
    Ok(())
}
