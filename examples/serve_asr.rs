//! Serving demo: batched ASR inference through the coordinator, reporting
//! latency percentiles, throughput and batch occupancy — the serving-side
//! claim of the paper (faster inference at equal quality) measured on
//! this testbed.
//!
//!     cargo run --release --example serve_asr -- [n_requests] [variant]
//!
//! variant ∈ {full, clustered-25, i-clustered-25} (default: both full and
//! i-clustered-25, for the head-to-head table).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use clustered_transformers::benchlib::Table;
use clustered_transformers::config::{find_repo_root, init_logging};
use clustered_transformers::coordinator::{
    BatchPolicy, InferenceEngine, ServeOptions,
};
use clustered_transformers::data::asr::{AsrCorpus, AsrSpec};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::{HostTensor, Runtime};

const D_FEAT: usize = 40;

fn serve_variant(rt: &Runtime, variant: &str, utts: &[(Vec<f32>, usize)])
                 -> Result<Vec<String>> {
    let model = format!("wsj-l6-{variant}");
    let fwd = format!("{model}.forward");
    let init = rt.load(&format!("{model}.init"))?;
    let params = init
        .run(&[HostTensor::scalar_i32(0)])?
        .remove(0)
        .into_f32()?;
    let engine = Arc::new(InferenceEngine::start(
        rt,
        &[fwd],
        params,
        ServeOptions {
            policy: BatchPolicy { max_batch: 4,
                                  max_wait: Duration::from_millis(10) },
            queue_capacity: 64,
            params_seed: 0,
        },
    )?);

    let t0 = Instant::now();
    let rxs: Vec<_> = utts
        .iter()
        .map(|(frames, len)| {
            engine.submit_blocking(frames.clone(), *len, D_FEAT).unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600))?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = &engine.metrics;
    let lat = m.latency.lock().unwrap();
    let row = vec![
        variant.to_string(),
        format!("{:.2}", utts.len() as f64 / wall),
        format!("{:.0}", lat.mean_us() / 1000.0),
        format!("{:.0}", lat.percentile_us(50.0) / 1000.0),
        format!("{:.0}", lat.percentile_us(95.0) / 1000.0),
        format!("{:.2}", m.occupancy()),
    ];
    drop(lat);
    let engine = Arc::try_unwrap(engine).ok();
    if let Some(e) = engine {
        e.shutdown();
    }
    Ok(row)
}

fn main() -> Result<()> {
    init_logging(false);
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize =
        args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let variants: Vec<String> = match args.get(1) {
        Some(v) => vec![v.clone()],
        None => vec!["full".into(), "clustered-25".into(),
                     "i-clustered-25".into()],
    };

    let rt = Runtime::open(find_repo_root().join("artifacts"))?;
    let corpus = AsrCorpus::new(AsrSpec::wsj(0));
    // pre-draw the workload so every variant sees identical requests
    let mut utts = Vec::new();
    let mut idx = 0u64;
    while utts.len() < n_requests {
        let b = corpus.batch(Split::Test, idx, 4);
        for s in 0..4 {
            if utts.len() >= n_requests {
                break;
            }
            let t = b.xlen[s] as usize;
            utts.push((
                b.x[s * 256 * D_FEAT..s * 256 * D_FEAT + t * D_FEAT]
                    .to_vec(),
                t,
            ));
        }
        idx += 1;
    }

    println!("== serving {} ASR requests per variant ==", utts.len());
    let mut table = Table::new(
        "serving head-to-head (WSJ-analog, 6 layers)",
        &["variant", "req/s", "mean ms", "p50 ms", "p95 ms", "occupancy"],
    );
    for v in &variants {
        match serve_variant(&rt, v, &utts) {
            Ok(row) => table.row(row),
            Err(e) => eprintln!("variant {v}: {e:#}"),
        }
    }
    table.emit();
    println!("(throughput ratio clustered/full mirrors the paper's \
              inference-speed claim)");
    Ok(())
}
