//! Std-only shim of the `log` facade (the registry is unreachable
//! offline).  Same shape as the real crate for the subset the repo uses:
//! the [`Log`] trait, a global logger + max level, and the five leveled
//! macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Global verbosity ceiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of one log record.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
}

/// Logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off

/// Returned when a logger was already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= max_level()
        }
        fn log(&self, _r: &Record) {
            HITS.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_against_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
    }

    #[test]
    fn macros_respect_max_level() {
        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::SeqCst);
        info!("hello {}", 1);
        debug!("filtered {}", 2);
        assert_eq!(HITS.load(Ordering::SeqCst), before + 1);
    }
}
