//! Offline shim of the `xla` (xla-rs / xla_extension) API subset that
//! `runtime/` consumes.
//!
//! The host-side [`Literal`] type is fully functional (typed storage,
//! reshape, tuple unpacking) so literal-preparation code paths and their
//! tests run without the native library.  The PJRT device side
//! ([`PjRtClient`], [`PjRtLoadedExecutable`]) returns a clear
//! "unavailable" error — swapping this vendored crate for the real
//! xla_extension bindings re-enables compiled execution with no source
//! changes elsewhere.

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type mirroring xla-rs (string-backed here).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new<M: Into<String>>(msg: M) -> Self {
        Self { msg: msg.into() }
    }

    fn unavailable() -> Self {
        Self::new(
            "PJRT backend unavailable: this build uses the offline stub \
             `vendor/xla` crate; swap it for the real xla_extension \
             bindings to execute compiled HLO",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// XLA element types (subset + a few extras for error paths).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
    Bf16,
}

#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host types that map onto XLA element types.
pub trait NativeType: Copy + 'static {
    fn element_type() -> ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn unwrap(s: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::F32(v)
    }
    fn unwrap(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::I32(v)
    }
    fn unwrap(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A typed host tensor (rank-1 on construction, reshaped to any rank).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    storage: Storage,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::wrap(data.to_vec()),
        }
    }

    /// Tuple literal (what lowered modules return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], storage: Storage::Tuple(parts) }
    }

    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(t) => t.len(),
        }
    }

    /// Same data, new dims (element count must match; `&[]` = scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have && !(dims.is_empty() && have == 1) {
            return Err(Error::new(format!(
                "reshape: {have} elements into shape {dims:?}"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), storage: self.storage.clone() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.storage {
            Storage::F32(_) => Ok(ElementType::F32),
            Storage::I32(_) => Ok(ElementType::S32),
            Storage::Tuple(_) => {
                Err(Error::new("tuple literal has no element type"))
            }
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage).ok_or_else(|| {
            Error::new(format!(
                "literal is not of element type {:?}",
                T::element_type()
            ))
        })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.storage {
            Storage::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module (text is retained verbatim in the stub).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("{e}")))?;
        Ok(Self { text })
    }
}

/// Computation handle built from an HLO proto.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Device buffer handle (stub: never materialized).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle (stub: execution always errors).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self, _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client (stub: construction reports the missing backend).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape_and_tuple() {
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.shape(), &[] as &[i64]);
        let t = Literal::tuple(vec![s.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.ty().is_err());
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn pjrt_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("unavailable"));
    }
}
