//! Std-only shim of `anyhow` (the registry is unreachable offline).
//!
//! Implements exactly the subset the crate uses: [`Error`] with a context
//! chain, [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the
//! [`Context`] extension trait.  `{:#}` formatting renders the full
//! outermost-first chain like the real crate.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Dynamic error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Prepend a context message (what `.context()` does).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.to_string_outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Extension trait adding `.context()` / `.with_context()` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::fs::read_to_string("/nonexistent/x");
        let _ = e.context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats_outermost_first() {
        let err = fails_io().unwrap_err();
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        let outer = format!("{err}");
        assert_eq!(outer, "reading config");
    }

    #[test]
    fn macros_build_errors() {
        let e: Error = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(11).is_err());
        assert_eq!(f(5).unwrap(), 5);
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).with_context(|| "x").unwrap(), 3);
    }
}
