//! Integration: the TCP JSON-lines server round-trips a transcription
//! request against a real compiled model.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use clustered_transformers::config::find_repo_root;
use clustered_transformers::coordinator::{InferenceEngine, ServeOptions};
use clustered_transformers::data::asr::{AsrCorpus, AsrSpec};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::{HostTensor, Runtime};
use clustered_transformers::server;

const FWD: &str = "wsj-l2-full.forward";

#[test]
fn tcp_round_trip_transcribes() {
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    clustered_transformers::config::init_logging(true);
    let rt = Runtime::open(dir).unwrap();
    if rt.program(FWD).is_err() {
        eprintln!("SKIP: {FWD} not lowered");
        return;
    }
    let init = rt.load("wsj-l2-full.init").unwrap();
    let params = init
        .run(&[HostTensor::scalar_i32(0)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let engine = Arc::new(
        InferenceEngine::start(&rt, &[FWD.to_string()], params,
                               ServeOptions::default())
        .unwrap(),
    );

    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_thread = std::thread::spawn(move || {
        server::serve(engine, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();

    // real utterance from the corpus
    let corpus = AsrCorpus::new(AsrSpec::wsj(0));
    let b = corpus.batch(Split::Test, 0, 1);
    let t = b.xlen[0] as usize;
    let frames = &b.x[..t * 40];

    let mut client = server::Client::connect(&addr.to_string()).unwrap();
    let reply = client.transcribe(99, frames, t, 40).unwrap();
    assert_eq!(reply.get("id").as_i64(), Some(99));
    let labels = reply.get("labels").as_arr().unwrap();
    // untrained model: decode may be empty or noisy, but must be valid ids
    for l in labels {
        let v = l.as_i64().unwrap();
        assert!((1..=20).contains(&v), "label {v} out of range");
    }
    assert!(reply.get("latency_us").as_i64().unwrap() > 0);

    // malformed request surfaces an error object, not a dropped conn
    let err = client.transcribe(1, &[0.0; 10], 3, 40);
    assert!(err.is_err());

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}
