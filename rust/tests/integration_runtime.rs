//! Integration: manifest → compile → execute real AOT artifacts.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially with a notice) when the artifacts directory is absent so
//! `cargo test` stays green on a fresh checkout.

use clustered_transformers::config::find_repo_root;
use clustered_transformers::coordinator::{trainer, DataFeed, TrainOptions};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::{HostTensor, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("runtime open"))
}

#[test]
fn manifest_loads_and_programs_are_well_formed() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.program_names();
    assert!(!names.is_empty());
    for name in &names {
        let p = rt.program(name).unwrap();
        assert!(!p.inputs.is_empty(), "{name} has no inputs");
        assert!(!p.file.is_empty());
        // every train program carries the full state signature
        if p.kind == "train" {
            for expected in ["params", "adam_m", "adam_v", "step", "seed"] {
                assert!(p.input_index(expected).is_some(),
                        "{name} missing input {expected}");
            }
        }
    }
}

#[test]
fn forward_program_executes_with_real_batch() {
    let Some(rt) = runtime_or_skip() else { return };
    let name = "copy-n64-i-clustered-8.forward";
    if rt.program(name).is_err() {
        eprintln!("SKIP: {name} not lowered");
        return;
    }
    let exe = rt.load(name).unwrap();
    let p = exe.program.clone();
    let feed = DataFeed::for_program(&p, 0).unwrap();
    // params from the init program of the same model
    let init = rt.load("copy-n64-i-clustered-8.init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(0)]).unwrap()
        .remove(0);
    let mut inputs = vec![params];
    inputs.extend(feed.forward_inputs(Split::Test, 0, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(7));
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    let logits = out[0].as_f32().unwrap();
    assert_eq!(logits.len(), p.batch_size() * p.seq_len() * 11);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn train_step_updates_params_and_loss_decreases_over_steps() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = "copy-n32-clustered-8";
    if rt.program(&format!("{model}.train")).is_err() {
        eprintln!("SKIP: {model}.train not lowered");
        return;
    }
    let opts = TrainOptions {
        steps: 30,
        eval_every: 15,
        patience: 0,
        eval_batches: 1,
        seed: 0,
        verbose: false,
    };
    let (ckpt, result) = trainer::train_model(&rt, model, &opts).unwrap();
    assert_eq!(result.steps_run, 30);
    assert!(result.final_loss.is_finite());
    let first = result.losses.first().unwrap().1;
    let last = result.losses.last().unwrap().1;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(!ckpt.params.is_empty());
    // training actually moved the parameters
    assert!(ckpt.params.iter().any(|&p| p != 0.0));
}

#[test]
fn deterministic_execution_same_inputs_same_outputs() {
    let Some(rt) = runtime_or_skip() else { return };
    let name = "copy-n64-i-clustered-8.forward";
    if rt.program(name).is_err() {
        return;
    }
    let exe = rt.load(name).unwrap();
    let p = exe.program.clone();
    let feed = DataFeed::for_program(&p, 3).unwrap();
    let init = rt.load("copy-n64-i-clustered-8.init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(1)]).unwrap().remove(0);
    let mut inputs = vec![params];
    inputs.extend(feed.forward_inputs(Split::Valid, 2, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(5));
    let a = exe.run(&inputs).unwrap().remove(0).into_f32().unwrap();
    let b = exe.run(&inputs).unwrap().remove(0).into_f32().unwrap();
    assert_eq!(a, b, "same inputs must give bit-identical outputs");
}

/// §Perf probe (run with `cargo test --release -- --ignored --nocapture`):
/// breaks one serving batch into input-prep vs execute vs readback so the
/// literal-caching optimisation in the dispatcher is quantified.
#[test]
#[ignore]
fn perf_probe_literal_prep_vs_execute() {
    let Some(rt) = runtime_or_skip() else { return };
    let name = "wsj-l6-full.forward";
    if rt.program(name).is_err() {
        return;
    }
    let exe = rt.load(name).unwrap();
    let p = exe.program.clone();
    let feed = DataFeed::for_program(&p, 0).unwrap();
    let init = rt.load("wsj-l6-full.init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(0)]).unwrap().remove(0);
    let mut inputs = vec![params];
    inputs.extend(feed.forward_inputs(Split::Test, 0, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(0));

    // warmup
    exe.run(&inputs).unwrap();
    let iters = 10;

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let _ = exe.prepare(&inputs).unwrap();
    }
    let prep = t0.elapsed().as_secs_f64() / iters as f64;

    let lits = exe.prepare(&inputs).unwrap();
    let t1 = std::time::Instant::now();
    for _ in 0..iters {
        let _ = exe.run_literals(&lits).unwrap();
    }
    let exec = t1.elapsed().as_secs_f64() / iters as f64;

    // params-only prep (the loop-invariant part the dispatcher now caches)
    let t2 = std::time::Instant::now();
    for _ in 0..iters {
        let _ = exe.prepare_one(0, &inputs[0]).unwrap();
    }
    let params_prep = t2.elapsed().as_secs_f64() / iters as f64;

    println!(
        "PERF {name}: input-prep {:.3}ms (params alone {:.3}ms), \
         execute+readback {:.3}ms, prep share {:.1}%, params share {:.1}%",
        prep * 1e3, params_prep * 1e3, exec * 1e3,
        100.0 * prep / (prep + exec),
        100.0 * params_prep / (prep + exec)
    );
}

#[test]
fn pallas_twin_forward_matches_ref_forward() {
    // The pallas-kernel artifact and the jnp-ref artifact of the same
    // model must produce (numerically) the same logits for the same
    // params and batch: the L1 kernel path composes end-to-end through
    // HLO → PJRT, not just under pytest.
    let Some(rt) = runtime_or_skip() else { return };
    let ref_name = "copy-n64-i-clustered-8.forward";
    let pallas_name = "copy-n64-i-clustered-8-pallas.forward";
    if rt.program(ref_name).is_err() || rt.program(pallas_name).is_err() {
        eprintln!("SKIP: pallas twin not lowered");
        return;
    }
    let ref_exe = rt.load(ref_name).unwrap();
    let pal_exe = rt.load(pallas_name).unwrap();
    let p = ref_exe.program.clone();
    let feed = DataFeed::for_program(&p, 0).unwrap();
    let init = rt.load("copy-n64-i-clustered-8.init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(2)]).unwrap().remove(0);
    let mut inputs = vec![params];
    inputs.extend(feed.forward_inputs(Split::Test, 1, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(9));
    let a = ref_exe.run(&inputs).unwrap().remove(0).into_f32().unwrap();
    let b = pal_exe.run(&inputs).unwrap().remove(0).into_f32().unwrap();
    assert_eq!(a.len(), b.len());
    let max_diff = a
        .iter()
        .zip(&b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-4, "pallas vs ref logits diverge: {max_diff}");
}
