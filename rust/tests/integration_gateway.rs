//! Integration: the multi-bucket native serving gateway end-to-end —
//! routing, padding, valid-length masking, per-bucket batching,
//! metrics — and its TCP JSON endpoint.  Fully native: needs no
//! compiled artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use clustered_transformers::attention::kernel_by_name;
use clustered_transformers::coordinator::{
    replay_blocking, session_reference, synthetic_decode_trace,
    synthetic_trace, unpadded_reference, Bucket, GatewayOptions,
    GatewayShape, ServingGateway,
};
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::server;

const SHAPE: GatewayShape = GatewayShape { heads: 2, dk: 8, dv: 8 };

fn gateway() -> ServingGateway {
    ServingGateway::start(
        SHAPE,
        vec![
            Bucket::native("i-clustered-4", 16, 4),
            Bucket::native("i-clustered-4", 32, 4),
            Bucket::native("i-clustered-4", 64, 2),
        ],
        GatewayOptions {
            max_wait: Duration::from_millis(2),
            ..GatewayOptions::default()
        },
    )
    .unwrap()
}

#[test]
fn mixed_length_trace_lands_in_the_right_buckets() {
    let gw = gateway();
    let trace = synthetic_trace(SHAPE, 4, 64, 24, 11);
    let responses = replay_blocking(&gw, trace.clone(), 4);
    for (item, resp) in trace.iter().zip(&responses) {
        let want = [16, 32, 64]
            .into_iter()
            .find(|&n| item.len <= n)
            .unwrap();
        assert_eq!(resp.bucket_seq_len, want, "len {}", item.len);
        assert_eq!(resp.out.len(), SHAPE.v_len(item.len));
        assert!(resp.out.iter().all(|x| x.is_finite()));
    }
    let per_bucket: Vec<u64> = gw
        .bucket_metrics()
        .iter()
        .map(|m| m.completed.load(Ordering::Relaxed))
        .collect();
    // exact per-bucket accounting, derived from the trace lengths
    let mut want = vec![0u64; 3];
    for t in &trace {
        let idx = [16, 32, 64].iter().position(|&n| t.len <= n).unwrap();
        want[idx] += 1;
    }
    assert_eq!(per_bucket, want);
    assert_eq!(per_bucket.iter().sum::<u64>(), 24);
    gw.shutdown();
}

#[test]
fn ragged_cobatch_responses_equal_the_unpadded_computation() {
    // the masking acceptance criterion, end-to-end: three staggered
    // ragged requests co-batched into one N=32 bucket flush must each
    // come back bit-identical to computing the request UNPADDED —
    // through the live threaded gateway (queues, batcher, shared pool)
    let seed = 23;
    let gw = ServingGateway::start(
        SHAPE,
        vec![Bucket::native("i-clustered-4", 32, 3)],
        GatewayOptions {
            max_wait: Duration::from_secs(10), // size trigger forms batch
            queue_capacity: 4,
            workers: 4,
            seed,
            ..GatewayOptions::default()
        },
    )
    .unwrap();
    let lens = [7usize, 19, 32];
    let mut rng = Xoshiro256::new(1);
    let reqs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> = lens
        .iter()
        .map(|&len| {
            (rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.qk_len(len)),
             rng.normal_vec(SHAPE.v_len(len)),
             len)
        })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(q, k, v, len)| {
            gw.submit_blocking(q.clone(), k.clone(), v.clone(), *len)
                .unwrap()
        })
        .collect();
    let kernel = kernel_by_name("i-clustered-4").unwrap();
    for (slot, (rx, (q, k, v, len))) in
        rxs.into_iter().zip(&reqs).enumerate()
    {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.batch_occupancy, 3, "requests were not co-batched");
        assert!(resp.masked);
        assert_eq!(resp.len, *len);
        let want = unpadded_reference(kernel.as_ref(), SHAPE, seed, slot,
                                      q, k, v, *len);
        assert_eq!(resp.out.len(), want.len());
        assert!(resp.out.iter().zip(&want)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "slot {slot} (len {len}) diverged from unpadded compute");
    }
    // masked flushes execute only valid rows: compute waste is zero,
    // the saved fraction is exactly the memory padding
    let m = &gw.bucket_metrics()[0];
    assert_eq!(m.compute_waste(), 0.0);
    assert!((m.compute_saved() - m.padding_waste()).abs() < 1e-12);
    gw.shutdown();
}

#[test]
fn decode_sessions_interleave_with_oneshot_traffic_end_to_end() {
    // decode sessions and ordinary ragged one-shots through the same
    // live gateway: every session step must equal the full unpadded
    // recompute of its history (session streams — invariant to what it
    // was co-batched with), and the one-shot traffic must still be
    // served
    let seed = 37;
    let gw = ServingGateway::start(
        SHAPE,
        vec![
            Bucket::native("i-clustered-4", 16, 4),
            Bucket::native("i-clustered-4", 32, 4),
            Bucket::native("i-clustered-4", 64, 2),
        ],
        GatewayOptions {
            max_wait: Duration::from_millis(2),
            seed,
            ..GatewayOptions::default()
        },
    )
    .unwrap();
    let mut trace = synthetic_trace(SHAPE, 4, 64, 10, 3);
    // two sessions: prefill 12, three steps of 6 — they grow from the
    // N=16 bucket into N=32 (route-up of grown sessions)
    trace.extend(synthetic_decode_trace(SHAPE, 12, 3, 6, 2, 9));
    let responses = replay_blocking(&gw, trace.clone(), 3);
    let kernel = kernel_by_name("i-clustered-4").unwrap();
    let mut hits = 0;
    for (item, resp) in trace.iter().zip(&responses) {
        assert_eq!(resp.len, item.len);
        match item.session {
            Some(sid) => {
                assert_eq!(resp.session, Some(sid));
                let want = session_reference(
                    kernel.as_ref(), SHAPE, seed, sid, &item.q, &item.k,
                    &item.v, item.len, resp.span_start);
                assert_eq!(resp.out.len(), want.len());
                assert!(resp.out.iter().zip(&want)
                            .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "session {sid} step at len {} diverged",
                        item.len);
                if resp.cache_hit == Some(true) {
                    hits += 1;
                }
            }
            None => {
                assert_eq!(resp.session, None);
                assert_eq!(resp.span_start, 0);
                assert_eq!(resp.out.len(), SHAPE.v_len(item.len));
            }
        }
    }
    // every non-prefill step hit the cache (2 sessions × 3 steps)
    assert_eq!(hits, 6);
    // grown sessions landed in the N=32 bucket and were counted
    let m = gw.bucket_metrics();
    assert!(m[1].session_route_up.load(Ordering::Relaxed) >= 2,
            "both sessions should route up into N=32");
    assert!(m[0].cache_misses.load(Ordering::Relaxed) >= 2,
            "prefills miss in the pinned N=16 bucket");
    gw.shutdown();
}

#[test]
fn tcp_gateway_serves_decode_sessions() {
    let gw = Arc::new(gateway());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let gw2 = gw.clone();
    let server_thread = std::thread::spawn(move || {
        server::serve_gateway(gw2, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    let mut client = server::Client::connect(&addr.to_string()).unwrap();

    // one session, prefill 8 then a step to 12 — full-history protocol
    let steps = synthetic_decode_trace(SHAPE, 8, 1, 4, 1, 3);
    let r0 = client
        .attend_session(1, &steps[0].q, &steps[0].k, &steps[0].v, 8, 5)
        .unwrap();
    assert_eq!(r0.get("session").as_i64(), Some(5));
    assert_eq!(r0.get("span_start").as_i64(), Some(0));
    assert_eq!(r0.get("cached").as_bool(), Some(false));
    assert_eq!(r0.get("out").as_arr().unwrap().len(), SHAPE.v_len(8));

    let r1 = client
        .attend_session(2, &steps[1].q, &steps[1].k, &steps[1].v, 12, 5)
        .unwrap();
    assert_eq!(r1.get("session").as_i64(), Some(5));
    assert_eq!(r1.get("span_start").as_i64(), Some(8));
    assert_eq!(r1.get("cached").as_bool(), Some(true));
    // the reply carries only the new rows
    assert_eq!(r1.get("out").as_arr().unwrap().len(),
               SHAPE.heads * 4 * SHAPE.dv);

    // a non-growing step surfaces an error object, session intact
    let err = client.attend_session(3, &steps[1].q, &steps[1].k,
                                    &steps[1].v, 12, 5);
    assert!(err.is_err());

    // ending the session releases its state; the same id then starts
    // fresh (new generation → the prefill misses again, no aliasing)
    let ended = client.end_session(5, 5).unwrap();
    assert_eq!(ended.get("ended").as_bool(), Some(true));
    let r2 = client
        .attend_session(6, &steps[0].q, &steps[0].k, &steps[0].v, 8, 5)
        .unwrap();
    assert_eq!(r2.get("span_start").as_i64(), Some(0));
    assert_eq!(r2.get("cached").as_bool(), Some(false));

    // one-shot replies carry no session fields
    let len = 8;
    let reply = client
        .attend(4, &vec![0.1; SHAPE.qk_len(len)],
                &vec![0.2; SHAPE.qk_len(len)],
                &vec![0.3; SHAPE.v_len(len)], len)
        .unwrap();
    assert!(reply.get("session").as_i64().is_none());

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}

#[test]
fn session_lifecycle_edges_leave_no_leaked_state() {
    // the long-running-server invariant, exercised over the TCP
    // protocol with the gateway handle in hand: every lifecycle edge —
    // unknown end, duplicate end, step-after-end, rejected-then-retried
    // steps — must leave zero leaked table entries (live_sessions) and
    // zero leaked cache rows (used_rows)
    let gw = Arc::new(gateway());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let gw2 = gw.clone();
    let server_thread = std::thread::spawn(move || {
        server::serve_gateway(gw2, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    let mut client = server::Client::connect(&addr.to_string()).unwrap();

    // `end` for a session that never existed: idempotent success,
    // creates nothing
    let r = client.end_session(1, 42).unwrap();
    assert_eq!(r.get("ended").as_bool(), Some(true));
    assert_eq!(r.get("was_live").as_bool(), Some(false));
    assert_eq!(gw.live_sessions(), 0);
    assert_eq!(gw.cache().used_rows(), 0);

    // a real session: prefill 8 rows, then one step to 12
    let steps = synthetic_decode_trace(SHAPE, 8, 1, 4, 1, 3);
    client
        .attend_session(2, &steps[0].q, &steps[0].k, &steps[0].v, 8, 7)
        .unwrap();
    assert_eq!(gw.live_sessions(), 1);
    assert!(gw.cache().used_rows() > 0, "prefill must cache rows");
    let r1 = client
        .attend_session(3, &steps[1].q, &steps[1].k, &steps[1].v, 12, 7)
        .unwrap();
    assert_eq!(r1.get("cached").as_bool(), Some(true));

    // first end tears the session down; the duplicate is a no-op —
    // and both leave the accounting at exactly zero
    let r = client.end_session(4, 7).unwrap();
    assert_eq!(r.get("was_live").as_bool(), Some(true));
    assert_eq!(gw.live_sessions(), 0);
    assert_eq!(gw.cache().used_rows(), 0);
    let r = client.end_session(5, 7).unwrap();
    assert_eq!(r.get("ended").as_bool(), Some(true));
    assert_eq!(r.get("was_live").as_bool(), Some(false));
    assert_eq!(gw.live_sessions(), 0);
    assert_eq!(gw.cache().used_rows(), 0);

    // a step after `end` is a fresh generation, not a resurrection:
    // span restarts at 0 and the prefill misses the cache again
    let r2 = client
        .attend_session(6, &steps[0].q, &steps[0].k, &steps[0].v, 8, 7)
        .unwrap();
    assert_eq!(r2.get("span_start").as_i64(), Some(0));
    assert_eq!(r2.get("cached").as_bool(), Some(false));
    assert_eq!(gw.live_sessions(), 1);

    // reject-then-retry: a non-growing step errors without touching
    // state, and the legitimate next step then succeeds from where the
    // session really is
    let rows_before = gw.cache().used_rows();
    let err = client.attend_session(7, &steps[0].q, &steps[0].k,
                                    &steps[0].v, 8, 7);
    assert!(err.is_err(), "non-growing step must be rejected");
    assert_eq!(gw.live_sessions(), 1);
    assert_eq!(gw.cache().used_rows(), rows_before,
               "rejected step must not change cached rows");
    let r3 = client
        .attend_session(8, &steps[1].q, &steps[1].k, &steps[1].v, 12, 7)
        .unwrap();
    assert_eq!(r3.get("span_start").as_i64(), Some(8));
    assert_eq!(r3.get("cached").as_bool(), Some(true));

    // an overlong step under a brand-new session id is rejected at
    // admission and must not commit a table entry for it
    let live = gw.live_sessions();
    let long = 65; // over the largest (N=64) bucket
    let err = client.attend_session(9, &vec![0.0; SHAPE.qk_len(long)],
                                    &vec![0.0; SHAPE.qk_len(long)],
                                    &vec![0.0; SHAPE.v_len(long)],
                                    long, 99);
    assert!(err.is_err());
    assert_eq!(gw.live_sessions(), live,
               "a rejected session must not appear in the table");

    // final teardown returns every counter to zero
    let r = client.end_session(10, 7).unwrap();
    assert_eq!(r.get("was_live").as_bool(), Some(true));
    assert_eq!(gw.live_sessions(), 0);
    assert_eq!(gw.cache().used_rows(), 0);

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}

#[test]
fn tcp_gateway_round_trips_attention_requests() {
    let gw = Arc::new(gateway());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let gw2 = gw.clone();
    let server_thread = std::thread::spawn(move || {
        server::serve_gateway(gw2, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();

    let len = 20; // routes to the N=32 bucket
    let q = vec![0.1f32; SHAPE.qk_len(len)];
    let k = vec![0.2f32; SHAPE.qk_len(len)];
    let v = vec![0.3f32; SHAPE.v_len(len)];
    let mut client = server::Client::connect(&addr.to_string()).unwrap();
    let reply = client.attend(7, &q, &k, &v, len).unwrap();
    assert_eq!(reply.get("id").as_i64(), Some(7));
    assert_eq!(reply.get("bucket_n").as_i64(), Some(32));
    assert_eq!(reply.get("masked").as_bool(), Some(true));
    assert_eq!(reply.get("out").as_arr().unwrap().len(),
               SHAPE.v_len(len));
    assert!(reply.get("latency_us").as_i64().unwrap() > 0);

    // malformed (too long for every bucket) surfaces an error object
    let long = 65;
    let err = client.attend(8, &vec![0.0; SHAPE.qk_len(long)],
                            &vec![0.0; SHAPE.qk_len(long)],
                            &vec![0.0; SHAPE.v_len(long)], long);
    assert!(err.is_err());

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server_thread.join().unwrap();
}
