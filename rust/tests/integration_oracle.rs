//! End-to-end tests of the golden-trace oracle harness through its
//! public API: record a suite to disk, replay it bit-exactly, prove an
//! injected perturbation turns the report red, and hold the *checked-in*
//! `identity-len1` fixture to its closed-form expectation — the one
//! fixture whose bytes were authored outside this crate, so it also
//! cross-checks the on-disk format (header schema, LE f32 frames,
//! FNV-1a-64 checksum) against an independent writer.

use std::path::PathBuf;

use clustered_transformers::jsonio;
use clustered_transformers::oracle::{
    self, identity_expected_frames, Fixture, FixtureSpec, Manifest,
    OracleReport, TolerancePolicy, TraceSpec,
};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ct-it-oracle-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small suite covering both serving paths: a native mixed trace
/// (one-shots + decode sessions) and a sharded ragged trace that spawns
/// real local shard workers over TCP.
fn small_suite() -> Vec<FixtureSpec> {
    vec![
        FixtureSpec {
            name: "it-mixed".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 4,
            dv: 4,
            buckets: vec![8, 16],
            seed: 101,
            masked: true,
            shards: 0,
            trace: TraceSpec::Mixed {
                min_len: 2, max_len: 12, count: 6,
                prefill: 4, steps: 2, step_len: 1, sessions: 2,
            },
        },
        FixtureSpec {
            name: "it-sharded".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 4,
            dv: 4,
            buckets: vec![8, 16],
            seed: 103,
            masked: true,
            shards: 2,
            trace: TraceSpec::Ragged { min_len: 2, max_len: 12, count: 6 },
        },
    ]
}

#[test]
fn record_then_replay_suite_is_bit_exact_and_reports_green() {
    let dir = temp_dir("roundtrip");
    let specs = small_suite();
    let recorded = oracle::record_suite(&dir, &specs, false).unwrap();
    assert_eq!(recorded, vec!["it-mixed", "it-sharded"]);
    let names = Manifest::load(&dir).unwrap().fixtures;
    assert_eq!(names, vec!["it-mixed", "it-sharded"]);

    let report =
        oracle::replay_suite(&dir, &names, &TolerancePolicy::default(),
                             false);
    assert!(report.passed(), "replay failures: {:#?}",
            report.fixtures.iter().filter(|f| !f.passed)
                  .collect::<Vec<_>>());
    for f in &report.fixtures {
        assert!(f.checked_responses > 0, "{}: nothing compared", f.name);
        assert_eq!(f.mismatched_elems, 0, "{}", f.name);
    }

    // the written report is valid JSON with a green verdict, and
    // writing it twice is byte-identical (no timestamps, no machine
    // noise — diffs of the report only ever show real changes)
    let rp = dir.join("oracle-report.json");
    report.write(&rp).unwrap();
    let first = std::fs::read(&rp).unwrap();
    report.write(&rp).unwrap();
    assert_eq!(first, std::fs::read(&rp).unwrap());
    let doc =
        jsonio::parse(&std::fs::read_to_string(&rp).unwrap()).unwrap();
    assert_eq!(doc.get("tool").as_str(), Some("ct oracle"));
    assert_eq!(doc.get("status").as_str(), Some("green"));
    assert_eq!(doc.get("fixtures").as_arr().map(Vec::len), Some(2));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_perturbation_turns_the_report_red() {
    let dir = temp_dir("perturb");
    let specs = vec![small_suite().remove(0)];
    oracle::record_suite(&dir, &specs, false).unwrap();
    let names = Manifest::load(&dir).unwrap().fixtures;

    let report =
        oracle::replay_suite(&dir, &names, &TolerancePolicy::default(),
                             true);
    assert!(!report.passed());
    let f = &report.fixtures[0];
    assert_eq!(f.mismatched_elems, 1);
    let diff = f.first_diff.as_ref().expect("diff located");
    assert_eq!((diff.response, diff.elem), (0, 0));
    assert_eq!(diff.got_bits ^ diff.want_bits, 1);
    assert!(f.notes.iter().any(|n| n.contains("perturbation")));

    let rp = dir.join("oracle-report.json");
    report.write(&rp).unwrap();
    let doc =
        jsonio::parse(&std::fs::read_to_string(&rp).unwrap()).unwrap();
    assert_eq!(doc.get("status").as_str(), Some("red"));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checked_in_identity_fixture_replays_green_against_the_closed_form() {
    // This is the tier-1 guard on the *committed* fixture files: if
    // oracle/fixtures/identity-len1.{json,bin} rot, drift from the
    // spec's closed form, or fail their own checksum, this test goes
    // red without any CI bootstrap step in the loop.
    let dir = oracle::default_fixture_dir();
    assert!(Fixture::exists(&dir, "identity-len1"),
            "checked-in fixture missing under {}", dir.display());
    assert!(Manifest::load(&dir).unwrap().fixtures
                .contains(&"identity-len1".to_string()),
            "manifest does not list identity-len1");

    // load() verifies format version, byte count and FNV checksum
    let fx = Fixture::load(&dir, "identity-len1").unwrap();
    let count = match fx.spec.trace {
        TraceSpec::IdentityLen1 { count } => count,
        ref other => panic!("unexpected trace spec {other:?}"),
    };
    let expected = identity_expected_frames(fx.spec.shape(), count);
    assert_eq!(fx.frames.len(), expected.len());
    for (i, (g, w)) in fx.frames.iter().zip(&expected).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "frame elem {i}");
    }

    // and the live gateway still reproduces it bit for bit
    let res =
        oracle::replay_fixture(&fx, &TolerancePolicy::default(), false);
    assert!(res.passed, "failures: {:?}", res.failures);
    assert_eq!(res.mismatched_elems, 0);
}

#[test]
fn perf_gate_self_check_and_report_merge_go_red_on_regression() {
    // the gate's own red-path proof must hold
    oracle::self_check(0.15).unwrap();

    // fabricate a regression and merge the verdict into a green report
    let dir = temp_dir("perfgate");
    let fresh = dir.join("fresh");
    let base = dir.join("baselines");
    std::fs::create_dir_all(&fresh).unwrap();
    std::fs::create_dir_all(&base).unwrap();
    let write = |d: &PathBuf, rps: f64| {
        std::fs::write(
            d.join("BENCH_it.json"),
            jsonio::to_string_pretty(
                &oracle::bench_doc("it", &[("row", rps)]))).unwrap();
    };
    write(&base, 1000.0);
    write(&fresh, 100.0); // −90%, far past the 15% band
    let gate = oracle::run_perf_gate(&fresh, &base, 0.15).unwrap();
    assert!(!gate.passed());

    let rp = dir.join("oracle-report.json");
    OracleReport::default().write(&rp).unwrap(); // green, no fixtures
    let ok = OracleReport::merge_perf_into(&rp, gate.to_value(),
                                           gate.passed()).unwrap();
    assert!(!ok);
    let doc =
        jsonio::parse(&std::fs::read_to_string(&rp).unwrap()).unwrap();
    assert_eq!(doc.get("status").as_str(), Some("red"));
    assert_eq!(doc.get("perf").get("status").as_str(), Some("fail"));

    let _ = std::fs::remove_dir_all(&dir);
}
