//! Integration: the sharded fan-out backend over real TCP shard
//! workers — binary-framed solves, sticky decode sessions, the
//! degraded-mode fallback when a shard is unreachable, and the
//! worker's survival of adversarial wire traffic (garbage headers,
//! truncated frames, mid-frame disconnects, frame-cap overflow).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use clustered_transformers::attention::{AttentionBackend, AttnBatch,
                                        CacheRef, CachingBackend, KvCache,
                                        NativeBackend, SeqOutcome,
                                        SessionRef, ShardEngine,
                                        ShardOptions, ShardedBackend};
use clustered_transformers::exec::ExecCtx;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::server;
use clustered_transformers::tensor::batch::BatchMatrix;

const KERNEL: &str = "i-clustered-4";

struct Worker {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

fn spawn_worker() -> Worker {
    let engine = Arc::new(ShardEngine::new(1));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let thread = std::thread::spawn(move || {
        server::serve_shard_worker(engine, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    Worker { addr: addr.to_string(), stop, thread }
}

impl Worker {
    fn shutdown(self) {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.join().unwrap();
    }
}

fn prefix(t: &BatchMatrix, len: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(1, t.heads, len, t.cols);
    for h in 0..t.heads {
        out.slice_mut(h)
            .copy_from_slice(&t.view(h).data[..len * t.cols]);
    }
    out
}

#[test]
fn tcp_shard_workers_match_native_and_survive_a_dead_shard() {
    clustered_transformers::config::init_logging(true);
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let addrs = vec![w1.addr.clone(), w2.addr.clone()];
    let opts = ShardOptions::default();
    let backend = ShardedBackend::over_tcp(KERNEL, &addrs, opts).unwrap();
    assert_eq!(backend.health_check(), vec![true, true]);

    let ctx = ExecCtx::sequential();
    let native = NativeBackend::by_name(KERNEL).unwrap();
    let mut rng = Xoshiro256::new(7);
    let q = BatchMatrix::randn(3, 2, 24, 8, &mut rng);
    let k = BatchMatrix::randn(3, 2, 24, 8, &mut rng);
    let v = BatchMatrix::randn(3, 2, 24, 8, &mut rng);

    // plain batch over the wire == native, both dense and ragged
    let batch = AttnBatch::new(&q, &k, &v, 11);
    assert!(backend.execute(&batch, &ctx)
        .bit_identical(&native.execute(&batch, &ctx)));
    let lens = [24usize, 5, 17];
    let ragged = AttnBatch::new(&q, &k, &v, 11).with_lens(&lens);
    assert!(backend.execute(&ragged, &ctx)
        .bit_identical(&native.execute(&ragged, &ctx)));

    // a decode session lands on its ring owner every step: prefill
    // misses, later steps hit the worker-side cache; every span equals
    // the single-host cached run bit for bit
    let oracle = CachingBackend::native(KERNEL, Arc::new(KvCache::unbounded()))
        .unwrap();
    let sid = 0xD00D_u64;
    let mut span = 0usize;
    for (i, len) in [10usize, 16, 24].into_iter().enumerate() {
        let (qp, kp, vp) = (prefix(&q, len), prefix(&k, len), prefix(&v, len));
        let blens = [len];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: sid, generation: 0 },
            span_start: span,
        })];
        let step = AttnBatch::new(&qp, &kp, &vp, 11)
            .with_lens(&blens)
            .with_sessions(&sessions);
        let (got, rep) = backend.execute_with_report(&step, &ctx);
        let (want, wrep) = oracle.execute_with_report(&step, &ctx);
        assert!(got.bit_identical(&want), "step {i} diverged");
        assert_eq!(rep, wrep, "step {i} outcome diverged");
        if i > 0 {
            assert!(matches!(rep[0], SeqOutcome::Hit { .. }),
                    "step {i}: session did not stick to its owner");
        }
        span = len;
    }
    backend.end_session(sid);

    // kill one worker: the backend retries, marks it down, and falls
    // back to local compute without changing a single bit
    w2.shutdown();
    let opts = ShardOptions {
        retries: 1,
        backoff: Duration::from_millis(1),
        ..ShardOptions::default()
    };
    let degraded = ShardedBackend::over_tcp(
        KERNEL, &[w1.addr.clone(), "127.0.0.1:1".to_string()], opts)
        .unwrap();
    assert_eq!(degraded.health_check(), vec![true, false]);
    assert!(degraded.execute(&ragged, &ctx)
        .bit_identical(&native.execute(&ragged, &ctx)));

    w1.shutdown();
}

// ---------------------------------------------------------------------------
// adversarial wire traffic: the worker must reply with an error where a
// header was parsed, and must keep serving fresh connections no matter
// how a client mangles its own
// ---------------------------------------------------------------------------

/// A raw client speaking the shard wire protocol by hand.
struct RawConn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawConn {
    fn open(addr: &str) -> Self {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        let writer = stream.try_clone().unwrap();
        Self { writer, reader: BufReader::new(stream) }
    }

    fn send_line(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn send_bytes(&mut self, bytes: &[u8]) {
        self.writer.write_all(bytes).unwrap();
        self.writer.flush().unwrap();
    }

    /// Read one reply line; `""` means the worker closed the stream.
    fn read_line(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        line
    }

    fn ping_ok(&mut self, id: i64) {
        self.send_line(&format!(r#"{{"op":"ping","id":{id}}}"#));
        let reply = self.read_line();
        assert!(reply.contains(&format!("\"id\":{id}"))
                    && reply.contains("true"),
                "ping {id} got {reply:?}");
    }
}

/// The worker is still healthy: a fresh connection answers a ping and a
/// real solve through the production backend matches native compute.
fn assert_worker_serves(addr: &str) {
    RawConn::open(addr).ping_ok(99);
    let backend = ShardedBackend::over_tcp(
        KERNEL, &[addr.to_string()], ShardOptions::default()).unwrap();
    let ctx = ExecCtx::sequential();
    let native = NativeBackend::by_name(KERNEL).unwrap();
    let mut rng = Xoshiro256::new(5);
    let q = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
    let k = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
    let v = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
    let batch = AttnBatch::new(&q, &k, &v, 3);
    assert!(backend.execute(&batch, &ctx)
        .bit_identical(&native.execute(&batch, &ctx)));
}

/// A syntactically valid solve header for KERNEL: batch 1, 2 heads,
/// 4 rows, dk = dv = 8 → each q/k/v frame is 1·2·4·8 = 64 f32.
fn small_solve_header(id: i64) -> String {
    format!(
        r#"{{"op":"solve","id":{id},"kernel":"{KERNEL}","batch":1,"heads":2,"rows":4,"dk":8,"dv":8,"seed":"0000000000000000","slice_base":"0000000000000000"}}"#
    )
}

#[test]
fn worker_rejects_garbage_json_header_and_closes() {
    let w = spawn_worker();
    let mut conn = RawConn::open(&w.addr);
    conn.send_line("{this is not json");
    let reply = conn.read_line();
    assert!(reply.contains("bad json"), "got {reply:?}");
    // the frame boundary is unknowable now — the worker must close
    assert_eq!(conn.read_line(), "", "worker kept a poisoned stream");
    assert_worker_serves(&w.addr);
    w.shutdown();
}

#[test]
fn worker_rejects_malformed_solve_header_and_closes() {
    let w = spawn_worker();
    let mut conn = RawConn::open(&w.addr);
    // valid JSON, but no shape fields: frames can't be sized
    conn.send_line(r#"{"op":"solve","id":5}"#);
    let reply = conn.read_line();
    assert!(reply.contains("\"error\""), "got {reply:?}");
    assert!(reply.contains("\"id\":5"), "error not keyed: {reply:?}");
    assert_eq!(conn.read_line(), "", "worker kept a poisoned stream");
    assert_worker_serves(&w.addr);
    w.shutdown();
}

#[test]
fn worker_refuses_frame_cap_overflow_headers() {
    let w = spawn_worker();
    let mut conn = RawConn::open(&w.addr);
    // 65536³·8 elements per frame: far past the 2²⁸-element sanity cap
    // (and past usize arithmetic on 32-bit) — the worker must refuse
    // before allocating anything
    conn.send_line(&format!(
        r#"{{"op":"solve","id":7,"kernel":"{KERNEL}","batch":65536,"heads":65536,"rows":65536,"dk":8,"dv":8,"seed":"0000000000000000","slice_base":"0000000000000000"}}"#
    ));
    let reply = conn.read_line();
    assert!(reply.contains("payload too large"), "got {reply:?}");
    assert_eq!(conn.read_line(), "", "worker kept a poisoned stream");
    assert_worker_serves(&w.addr);
    w.shutdown();
}

#[test]
fn worker_survives_truncated_frames_and_midframe_disconnects() {
    let w = spawn_worker();

    // half a frame then FIN: read_f32s hits EOF mid-frame, the handler
    // dies without replying, the accept loop keeps serving
    let mut conn = RawConn::open(&w.addr);
    conn.send_line(&small_solve_header(11));
    conn.send_bytes(&vec![0u8; 64 * 4 / 2]);
    conn.writer.shutdown(std::net::Shutdown::Write).unwrap();
    assert_eq!(conn.read_line(), "",
               "no reply can be framed for a truncated request");
    drop(conn);

    // abrupt mid-frame disconnect (no FIN handshake discipline): same
    // story from a second client
    let mut conn = RawConn::open(&w.addr);
    conn.send_line(&small_solve_header(12));
    conn.send_bytes(&vec![0u8; 7]); // not even one whole f32
    drop(conn);

    assert_worker_serves(&w.addr);
    w.shutdown();
}

#[test]
fn worker_reports_engine_errors_and_keeps_the_connection() {
    let w = spawn_worker();
    let mut conn = RawConn::open(&w.addr);
    // header parses and frames are fully consumed, so the stream stays
    // in sync — an unknown kernel is an engine error, not a wire error
    conn.send_line(
        r#"{"op":"solve","id":21,"kernel":"no-such-kernel","batch":1,"heads":2,"rows":4,"dk":8,"dv":8,"seed":"0000000000000000","slice_base":"0000000000000000"}"#,
    );
    conn.send_bytes(&vec![0u8; 3 * 64 * 4]); // q, k, v frames
    let reply = conn.read_line();
    assert!(reply.contains("\"error\""), "got {reply:?}");
    assert!(reply.contains("\"id\":21"), "error not keyed: {reply:?}");
    // the SAME connection keeps working…
    conn.ping_ok(22);
    // …including unknown ops, which are error replies, not closes
    conn.send_line(r#"{"op":"frobnicate","id":23}"#);
    let reply = conn.read_line();
    assert!(reply.contains("unknown op"), "got {reply:?}");
    conn.ping_ok(24);
    drop(conn);
    assert_worker_serves(&w.addr);
    w.shutdown();
}
