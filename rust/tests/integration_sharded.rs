//! Integration: the sharded fan-out backend over real TCP shard
//! workers — binary-framed solves, sticky decode sessions, and the
//! degraded-mode fallback when a shard is unreachable.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use clustered_transformers::attention::{AttentionBackend, AttnBatch,
                                        CacheRef, CachingBackend, KvCache,
                                        NativeBackend, SeqOutcome,
                                        SessionRef, ShardEngine,
                                        ShardOptions, ShardedBackend};
use clustered_transformers::exec::ExecCtx;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::server;
use clustered_transformers::tensor::batch::BatchMatrix;

const KERNEL: &str = "i-clustered-4";

struct Worker {
    addr: String,
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
}

fn spawn_worker() -> Worker {
    let engine = Arc::new(ShardEngine::new(1));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let thread = std::thread::spawn(move || {
        server::serve_shard_worker(engine, "127.0.0.1:0", stop2, move |a| {
            addr_tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    Worker { addr: addr.to_string(), stop, thread }
}

impl Worker {
    fn shutdown(self) {
        self.stop.store(true, Ordering::Relaxed);
        self.thread.join().unwrap();
    }
}

fn prefix(t: &BatchMatrix, len: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(1, t.heads, len, t.cols);
    for h in 0..t.heads {
        out.slice_mut(h)
            .copy_from_slice(&t.view(h).data[..len * t.cols]);
    }
    out
}

#[test]
fn tcp_shard_workers_match_native_and_survive_a_dead_shard() {
    clustered_transformers::config::init_logging(true);
    let w1 = spawn_worker();
    let w2 = spawn_worker();
    let addrs = vec![w1.addr.clone(), w2.addr.clone()];
    let opts = ShardOptions::default();
    let backend = ShardedBackend::over_tcp(KERNEL, &addrs, opts).unwrap();
    assert_eq!(backend.health_check(), vec![true, true]);

    let ctx = ExecCtx::sequential();
    let native = NativeBackend::by_name(KERNEL).unwrap();
    let mut rng = Xoshiro256::new(7);
    let q = BatchMatrix::randn(3, 2, 24, 8, &mut rng);
    let k = BatchMatrix::randn(3, 2, 24, 8, &mut rng);
    let v = BatchMatrix::randn(3, 2, 24, 8, &mut rng);

    // plain batch over the wire == native, both dense and ragged
    let batch = AttnBatch::new(&q, &k, &v, 11);
    assert!(backend.execute(&batch, &ctx)
        .bit_identical(&native.execute(&batch, &ctx)));
    let lens = [24usize, 5, 17];
    let ragged = AttnBatch::new(&q, &k, &v, 11).with_lens(&lens);
    assert!(backend.execute(&ragged, &ctx)
        .bit_identical(&native.execute(&ragged, &ctx)));

    // a decode session lands on its ring owner every step: prefill
    // misses, later steps hit the worker-side cache; every span equals
    // the single-host cached run bit for bit
    let oracle = CachingBackend::native(KERNEL, Arc::new(KvCache::unbounded()))
        .unwrap();
    let sid = 0xD00D_u64;
    let mut span = 0usize;
    for (i, len) in [10usize, 16, 24].into_iter().enumerate() {
        let (qp, kp, vp) = (prefix(&q, len), prefix(&k, len), prefix(&v, len));
        let blens = [len];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: sid, generation: 0 },
            span_start: span,
        })];
        let step = AttnBatch::new(&qp, &kp, &vp, 11)
            .with_lens(&blens)
            .with_sessions(&sessions);
        let (got, rep) = backend.execute_with_report(&step, &ctx);
        let (want, wrep) = oracle.execute_with_report(&step, &ctx);
        assert!(got.bit_identical(&want), "step {i} diverged");
        assert_eq!(rep, wrep, "step {i} outcome diverged");
        if i > 0 {
            assert!(matches!(rep[0], SeqOutcome::Hit { .. }),
                    "step {i}: session did not stick to its owner");
        }
        span = len;
    }
    backend.end_session(sid);

    // kill one worker: the backend retries, marks it down, and falls
    // back to local compute without changing a single bit
    w2.shutdown();
    let opts = ShardOptions {
        retries: 1,
        backoff: Duration::from_millis(1),
        ..ShardOptions::default()
    };
    let degraded = ShardedBackend::over_tcp(
        KERNEL, &[w1.addr.clone(), "127.0.0.1:1".to_string()], opts)
        .unwrap();
    assert_eq!(degraded.health_check(), vec![true, false]);
    assert!(degraded.execute(&ragged, &ctx)
        .bit_identical(&native.execute(&ragged, &ctx)));

    w1.shutdown();
}
