//! Integration: the serving coordinator end-to-end — routing, dynamic
//! batching, execution, metrics — against a real compiled ASR forward
//! program.

use std::sync::Arc;
use std::time::Duration;

use clustered_transformers::config::find_repo_root;
use clustered_transformers::coordinator::{
    BatchPolicy, InferenceEngine, ServeOptions,
};
use clustered_transformers::data::asr::{AsrCorpus, AsrSpec};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::{HostTensor, Runtime};

const FWD: &str = "wsj-l2-full.forward";
const MODEL: &str = "wsj-l2-full";
const D_FEAT: usize = 40;

fn engine_or_skip() -> Option<(Arc<InferenceEngine>, AsrCorpus)> {
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return None;
    }
    clustered_transformers::config::init_logging(true);
    let rt = Runtime::open(dir).ok()?;
    if rt.program(FWD).is_err() {
        eprintln!("SKIP: {FWD} not lowered");
        return None;
    }
    let init = rt.load(&format!("{MODEL}.init")).unwrap();
    let params = init
        .run(&[HostTensor::scalar_i32(0)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let opts = ServeOptions {
        policy: BatchPolicy { max_batch: 4,
                              max_wait: Duration::from_millis(20) },
        queue_capacity: 32,
        params_seed: 0,
    };
    let engine = Arc::new(
        InferenceEngine::start(&rt, &[FWD.to_string()], params, opts)
            .unwrap(),
    );
    let corpus = AsrCorpus::new(AsrSpec::wsj(0));
    Some((engine, corpus))
}

fn utterances(corpus: &AsrCorpus, n: usize) -> Vec<(Vec<f32>, usize)> {
    let mut out = Vec::new();
    let mut idx = 0u64;
    while out.len() < n {
        let b = corpus.batch(Split::Test, idx, 4);
        for s in 0..4 {
            if out.len() >= n {
                break;
            }
            let t = b.xlen[s] as usize;
            let frames =
                b.x[s * 256 * D_FEAT..s * 256 * D_FEAT + t * D_FEAT]
                    .to_vec();
            out.push((frames, t));
        }
        idx += 1;
    }
    out
}

#[test]
fn requests_round_trip_with_correct_shapes() {
    let Some((engine, corpus)) = engine_or_skip() else { return };
    let utts = utterances(&corpus, 6);
    let mut rxs = Vec::new();
    for (frames, len) in utts {
        rxs.push((len, engine
            .submit_blocking(frames, len, D_FEAT)
            .unwrap()));
    }
    for (len, rx) in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.valid_len, len);
        assert_eq!(resp.vocab, 21); // 20 phones + blank
        assert_eq!(resp.logits.len(), 256 * 21);
        assert!(resp.logits.iter().all(|x| x.is_finite()));
        assert!(resp.batch_occupancy >= 1 && resp.batch_occupancy <= 4);
    }
    assert_eq!(engine.metrics.completed
               .load(std::sync::atomic::Ordering::Relaxed), 6);
}

#[test]
fn batcher_coalesces_concurrent_requests() {
    let Some((engine, corpus)) = engine_or_skip() else { return };
    let utts = utterances(&corpus, 8);
    // submit all 8 quickly; with max_batch 4 the engine should form
    // batches with occupancy > 1 (the first may flush alone on deadline)
    let rxs: Vec<_> = utts
        .into_iter()
        .map(|(frames, len)| engine.submit_blocking(frames, len, D_FEAT)
             .unwrap())
        .collect();
    let mut max_occ = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        max_occ = max_occ.max(resp.batch_occupancy);
    }
    assert!(max_occ >= 2, "no batching observed (max occupancy {max_occ})");
    assert!(engine.metrics.occupancy() > 1.0);
}

#[test]
fn overlong_requests_are_rejected() {
    let Some((engine, _)) = engine_or_skip() else { return };
    let too_long = 257; // bucket is N=256
    let frames = vec![0.0; too_long * D_FEAT];
    assert!(engine.submit(frames, too_long, D_FEAT).is_err());
    assert_eq!(
        engine.metrics.completed
            .load(std::sync::atomic::Ordering::Relaxed), 0);
}
