//! Cross-implementation golden check: the Rust reference attention must
//! agree with the jnp oracle *through the full HLO → PJRT path* on
//! identical inputs (q, k, v, groups).  Together with pytest (Pallas ≡
//! jnp), this closes the triangle jnp ≡ Pallas ≡ Rust.

use clustered_transformers::attention;
use clustered_transformers::clustering::Clustering;
use clustered_transformers::config::find_repo_root;
use clustered_transformers::prng::Xoshiro256;
use clustered_transformers::runtime::{HostTensor, Runtime};
use clustered_transformers::tensor::Matrix;

const N: usize = 64;
const DK: usize = 16;
const DV: usize = 16;
const C: usize = 8;
const TOPK: usize = 8;

fn max_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn rust_attention_matches_jnp_oracle_via_hlo() {
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let rt = Runtime::open(dir).unwrap();
    let Ok(exe) = rt.load("attncheck-n64.check") else {
        eprintln!("SKIP: attncheck not lowered");
        return;
    };

    let mut rng = Xoshiro256::new(42);
    let q = Matrix::randn(N, DK, &mut rng);
    let k = Matrix::randn(N, DK, &mut rng);
    let v = Matrix::randn(N, DV, &mut rng);
    // groups from the Rust clustering substrate — then shared with jnp
    let cl = clustered_transformers::clustering::cluster_queries(
        &q, C, 31, 5, &mut rng);
    let groups_i32: Vec<i32> = cl.groups.iter().map(|&g| g as i32).collect();

    let outputs = exe
        .run(&[
            HostTensor::F32(q.data.clone()),
            HostTensor::F32(k.data.clone()),
            HostTensor::F32(v.data.clone()),
            HostTensor::I32(groups_i32),
        ])
        .unwrap();
    let hlo_full = outputs[0].as_f32().unwrap();
    let hlo_clustered = outputs[1].as_f32().unwrap();
    let hlo_improved = outputs[2].as_f32().unwrap();

    // Rust-native counterparts on the same inputs/groups
    let rust_full = attention::full_attention(&q, &k, &v);
    let counts = {
        let mut c = vec![0u32; C];
        for &g in &cl.groups {
            c[g as usize] += 1;
        }
        c
    };
    let cl_shared = Clustering { n_clusters: C, groups: cl.groups.clone(),
                                 counts, cost: 0 };
    let rust_clustered =
        attention::clustered_attention(&q, &k, &v, &cl_shared);
    let rust_improved = attention::improved_clustered_attention(
        &q, &k, &v, &cl_shared, TOPK);

    let d_full = max_diff(&rust_full.data, hlo_full);
    let d_clus = max_diff(&rust_clustered.data, hlo_clustered);
    let d_impr = max_diff(&rust_improved.data, hlo_improved);
    eprintln!("max|Δ| full={d_full:.2e} clustered={d_clus:.2e} \
               improved={d_impr:.2e}");
    assert!(d_full < 1e-4, "full attention disagrees: {d_full}");
    assert!(d_clus < 1e-4, "clustered attention disagrees: {d_clus}");
    assert!(d_impr < 1e-3, "improved clustered disagrees: {d_impr}");
}

#[test]
fn improved_is_closer_to_full_than_clustered_on_hlo_outputs() {
    // Proposition 2 holds on the actual artifact outputs too.
    let dir = find_repo_root().join("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::open(dir).unwrap();
    let Ok(exe) = rt.load("attncheck-n64.check") else { return };

    let mut rng = Xoshiro256::new(7);
    let q = Matrix::randn(N, DK, &mut rng);
    let k = Matrix::randn(N, DK, &mut rng);
    let v = Matrix::randn(N, DV, &mut rng);
    let cl = clustered_transformers::clustering::cluster_queries(
        &q, C, 31, 5, &mut rng);
    let groups: Vec<i32> = cl.groups.iter().map(|&g| g as i32).collect();
    let outputs = exe
        .run(&[
            HostTensor::F32(q.data.clone()),
            HostTensor::F32(k.data.clone()),
            HostTensor::F32(v.data.clone()),
            HostTensor::I32(groups),
        ])
        .unwrap();
    let full = outputs[0].as_f32().unwrap();
    let clustered = outputs[1].as_f32().unwrap();
    let improved = outputs[2].as_f32().unwrap();
    // aggregate L2 error of the *values* (a proxy implied by prop. 2)
    let err = |a: &[f32]| -> f64 {
        a.iter()
            .zip(full)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum::<f64>()
            .sqrt()
    };
    let e_c = err(clustered);
    let e_i = err(improved);
    eprintln!("value error clustered={e_c:.4} improved={e_i:.4}");
    assert!(e_i <= e_c, "improved ({e_i}) worse than clustered ({e_c})");
}
