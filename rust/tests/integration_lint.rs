//! End-to-end tests of the `ct lint` static-analysis pass through its
//! public API: known-good and known-bad fixtures per rule family, the
//! suppression contract (a reason is mandatory), the red-path
//! self-check probes, and byte-stability of the report over the real
//! tree (two runs must produce identical bytes — the property that
//! makes `lint-report.json` diffable in review).

use std::path::PathBuf;

use clustered_transformers::lint::{self, SourceSet};

/// Assemble a [`SourceSet`] from literal files, with empty drift docs
/// and a minimal wire allowlist.
fn set(files: &[(&str, &str)]) -> SourceSet {
    SourceSet {
        files: files
            .iter()
            .map(|(p, t)| (p.to_string(), t.to_string()))
            .collect(),
        docs: vec![
            ("README.md".to_string(), String::new()),
            ("docs/ARCHITECTURE.md".to_string(), String::new()),
        ],
        wire_allow: vec!["id".to_string(), "ok".to_string()],
    }
}

fn rules_fired(rep: &lint::report::LintReport) -> Vec<String> {
    rep.violations.iter().map(|v| v.rule.clone()).collect()
}

/// Repo root: the parent of the crate dir (`rust/`).
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

// ---------------------------------------------------------------------------
// per-rule good/bad fixtures
// ---------------------------------------------------------------------------

#[test]
fn det_float_reduce_bad_and_good() {
    let bad = set(&[(
        "attention/k.rs",
        "//! ct-contract: bit-exact\n\
         fn f(xs: &[f32]) -> f32 { xs.iter().sum() }\n",
    )]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"det-float-reduce".to_string()));

    // max/min folds are order-insensitive and exempt
    let good = set(&[(
        "attention/k.rs",
        "//! ct-contract: bit-exact\n\
         fn f(xs: &[f32]) -> f32 {\n\
             xs.iter().fold(f32::NEG_INFINITY, f32::max)\n\
         }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn det_float_accum_flags_loops_not_counters() {
    let bad = set(&[(
        "tensor/k.rs",
        "//! ct-contract: bit-exact\n\
         fn f(xs: &[f32], acc: &mut [f32]) {\n\
             for (i, x) in xs.iter().enumerate() {\n\
                 acc[i % 2] += x * 2.0;\n\
             }\n\
         }\n",
    )]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"det-float-accum".to_string()));

    // integer counters in loops are not float accumulation
    let good = set(&[(
        "tensor/k.rs",
        "//! ct-contract: bit-exact\n\
         fn f(xs: &[f32]) -> usize {\n\
             let mut n = 0usize;\n\
             for _x in xs {\n\
                 n += 1;\n\
             }\n\
             n\n\
         }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn det_map_iter_flags_hash_containers() {
    let bad = set(&[(
        "exec/k.rs",
        "//! ct-contract: bit-exact\n\
         use std::collections::HashMap;\n\
         fn f() { let _m: HashMap<u32, u32> = HashMap::new(); }\n",
    )]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"det-map-iter".to_string()));

    let good = set(&[(
        "exec/k.rs",
        "//! ct-contract: bit-exact\n\
         use std::collections::BTreeMap;\n\
         fn f() { let _m: BTreeMap<u32, u32> = BTreeMap::new(); }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn det_entropy_scope_excludes_prng() {
    let src = "fn f() { let _t = std::time::Instant::now(); }\n";
    let bad = set(&[("clustering/k.rs", src)]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"det-entropy".to_string()));

    // prng/ and benchlib/ are the sanctioned homes
    let good = set(&[("prng/k.rs", src), ("benchlib/k.rs", src)]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn det_seed_arith_wants_prng_helpers() {
    let bad = set(&[(
        "clustering/k.rs",
        "fn f(seed: u64) -> u64 { seed ^ 0x9E37 }\n",
    )]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"det-seed-arith".to_string()));

    let good = set(&[(
        "clustering/k.rs",
        "fn f(seed: u64, s: u64) -> u64 { slice_stream(seed, s).next() }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn panic_rules_cover_the_serving_surface() {
    let bad = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         fn f(v: Vec<u64>, i: usize) -> u64 {\n\
             let a = v.first().unwrap();\n\
             let b = v.last().expect(\"b\");\n\
             if a > b { panic!(\"nope\"); }\n\
             v[i]\n\
         }\n",
    )]);
    let fired = rules_fired(&lint::analyze(&bad));
    for rule in ["panic-unwrap", "panic-expect", "panic-macro",
                 "panic-index"] {
        assert!(fired.contains(&rule.to_string()), "missing {rule}");
    }

    // error-return idiom passes; test code is exempt entirely
    let good = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         fn f(v: &[u64], i: usize) -> Option<u64> {\n\
             v.get(i).copied()\n\
         }\n\
         #[cfg(test)]\n\
         mod tests {\n\
             #[test]\n\
             fn t() { assert_eq!(super::f(&[3], 0).unwrap(), 3); }\n\
         }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn panic_rules_skip_files_outside_the_surface() {
    // attention/full.rs-style kernel files may unwrap on programmer
    // error — panic rules are scoped, not global
    let kernel = set(&[(
        "attention/k.rs",
        "//! ct-contract: bit-exact\n\
         fn f(v: Vec<u64>) -> u64 { *v.first().unwrap() }\n",
    )]);
    let rep = lint::analyze(&kernel);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn wire_field_allowlist() {
    let bad = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         fn f() { emit(vec![(\"id\", 1), (\"rogue\", 2)]); }\n",
    )]);
    let rep = lint::analyze(&bad);
    let wire: Vec<_> = rep
        .violations
        .iter()
        .filter(|v| v.rule == "wire-field")
        .collect();
    assert_eq!(wire.len(), 1);
    assert!(wire[0].msg.contains("rogue"));

    // allowlisted fields pass, and non-wire files are never checked
    let good = set(&[
        ("server/k.rs",
         "//! ct-contract: panic-free\n\
          fn f() { emit(vec![(\"id\", 1), (\"ok\", 2)]); }\n"),
        ("oracle/k.rs",
         "//! ct-contract: panic-free\n\
          fn f() { emit(vec![(\"not_wire\", 1)]); }\n"),
    ]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn contract_header_is_mandatory_in_bit_dirs() {
    let bad = set(&[("tensor/k.rs", "fn f() {}\n")]);
    assert!(rules_fired(&lint::analyze(&bad))
        .contains(&"contract-header".to_string()));

    let good = set(&[(
        "tensor/k.rs",
        "//! ct-contract: bit-exact\nfn f() {}\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

#[test]
fn doc_family_drift_requires_both_docs() {
    let registry =
        "//! ct-contract: bit-exact\n\
         pub static REGISTRY: &[KernelFamily] = &[\n\
             KernelFamily { key: \"full\", parse: parse_full },\n\
         ];\n";
    let mut missing = set(&[("attention/mod.rs", registry)]);
    missing.docs = vec![
        ("README.md".to_string(), "mentions `full` here".to_string()),
        ("docs/ARCHITECTURE.md".to_string(), String::new()),
    ];
    let rep = lint::analyze(&missing);
    let drift: Vec<_> = rep
        .violations
        .iter()
        .filter(|v| v.rule == "doc-family-drift")
        .collect();
    assert_eq!(drift.len(), 1);
    assert!(drift[0].msg.contains("ARCHITECTURE"));

    let mut both = set(&[("attention/mod.rs", registry)]);
    both.docs = vec![
        ("README.md".to_string(), "the `full` kernel".to_string()),
        ("docs/ARCHITECTURE.md".to_string(), "full".to_string()),
    ];
    let rep = lint::analyze(&both);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
}

// ---------------------------------------------------------------------------
// the suppression contract
// ---------------------------------------------------------------------------

#[test]
fn suppression_requires_a_reason() {
    // reasonless: the directive itself is a violation AND the
    // underlying hit still fires
    let bad = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         fn f(v: Vec<u8>) -> u8 {\n\
             // ct-lint: allow(panic-unwrap)\n\
             *v.first().unwrap()\n\
         }\n",
    )]);
    let fired = rules_fired(&lint::analyze(&bad));
    assert!(fired.contains(&"lint-no-reason".to_string()));
    assert!(fired.contains(&"panic-unwrap".to_string()));

    // with a reason the hit moves to the suppressions section
    let good = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         fn f(v: Vec<u8>) -> u8 {\n\
             // ct-lint: allow(panic-unwrap, reason = \"v non-empty by caller contract\")\n\
             *v.first().unwrap()\n\
         }\n",
    )]);
    let rep = lint::analyze(&good);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
    assert_eq!(rep.suppressions.len(), 1);
    assert_eq!(rep.suppressions[0].rule, "panic-unwrap");
    assert_eq!(rep.suppressions[0].reason,
               "v non-empty by caller contract");
}

#[test]
fn unknown_rule_in_directive_is_flagged() {
    let setb = set(&[(
        "server/k.rs",
        "//! ct-contract: panic-free\n\
         // ct-lint: allow(no-such-rule, reason = \"typo\")\n\
         fn f() {}\n",
    )]);
    assert!(rules_fired(&lint::analyze(&setb))
        .contains(&"lint-unknown-rule".to_string()));
}

#[test]
fn file_scope_suppression_covers_the_whole_file() {
    let setb = set(&[(
        "coordinator/k.rs",
        "//! ct-contract: panic-free\n\
         //! ct-lint: allow(det-entropy, reason = \"timing metrics only\")\n\
         fn f() { let _a = std::time::Instant::now(); }\n\
         fn g() { let _b = std::time::Instant::now(); }\n",
    )]);
    let rep = lint::analyze(&setb);
    assert!(rep.passed(), "violations: {:?}", rep.violations);
    assert_eq!(rep.suppressions.len(), 2);
}

// ---------------------------------------------------------------------------
// the real tree: self-check red path + byte stability
// ---------------------------------------------------------------------------

#[test]
fn self_check_probes_trip_every_rule_on_the_real_tree() {
    let sc = lint::self_check(&repo_root()).expect("self-check runs");
    assert!(sc.missed.is_empty(),
            "rules that missed their probe: {:?}", sc.missed);
    assert!(sc.injected >= lint::rules::RULE_IDS.len() - 1,
            "only {} injected violations detected", sc.injected);
}

#[test]
fn report_is_byte_stable_across_runs() {
    let root = repo_root();
    let a = lint::run(&root).expect("first run");
    let b = lint::run(&root).expect("second run");
    assert_eq!(a.render(), b.render(),
               "two lint runs over the same tree must render \
                identical bytes");
    // and the render round-trips through the jsonio parser
    let v = clustered_transformers::jsonio::parse(&a.render())
        .expect("report parses");
    assert_eq!(v.get("version").as_usize(), Some(1));
    assert_eq!(v.get("files_scanned").as_usize(),
               Some(a.files_scanned));
}
