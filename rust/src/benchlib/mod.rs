//! Micro-benchmark harness (criterion is unavailable offline — DESIGN.md §5).
//!
//! `cargo bench` targets are `harness = false` binaries built on this
//! module: warmup, adaptive iteration counts, robust statistics and
//! aligned table output matching the paper's table/figure rows.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Median sample (nearest-rank), seconds.
    pub p50_s: f64,
    /// 99th-percentile sample (nearest-rank), seconds.
    pub p99_s: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Self {
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / n;
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let rank = (p / 100.0 * (sorted.len() - 1) as f64).round();
            sorted[rank as usize]
        };
        Stats {
            iters: samples.len(),
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().copied().fold(0.0, f64::max),
            p50_s: pct(50.0),
            p99_s: pct(99.0),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Benchmark a closure: `warmup` untimed runs, then timed runs until both
/// `min_iters` and `min_time` are satisfied (capped at `max_iters`).
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, min_iters: usize,
                         min_time: Duration, max_iters: usize) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while (samples.len() < min_iters || start.elapsed() < min_time)
        && samples.len() < max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Stats::from_samples(&samples)
}

/// Quick preset: 1 warmup, >=3 iters or 1s.
pub fn quick<F: FnMut()>(f: F) -> Stats {
    bench(f, 1, 3, Duration::from_secs(1), 50)
}

/// Aligned plain-text table writer (also emits machine-readable TSV).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out += &fmt_row(&self.headers, &widths);
        out.push('\n');
        out += &"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len());
        out.push('\n');
        for row in &self.rows {
            out += &fmt_row(row, &widths);
            out.push('\n');
        }
        out
    }

    /// Print the table and append a TSV copy under `target/bench-results/`.
    pub fn emit(&self) {
        println!("{}", self.render());
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        let mut tsv = self.headers.join("\t") + "\n";
        for row in &self.rows {
            tsv += &(row.join("\t") + "\n");
        }
        let _ = std::fs::write(dir.join(format!("{slug}.tsv")), tsv);
    }
}

/// Throughput in rows per second given rows processed per timed run.
/// "Rows" are query positions: a (B, H, N, D) batched attention call
/// processes `B·H·N` rows — the unit the fig. 4 batched table reports.
pub fn rows_per_sec(rows_per_run: usize, st: &Stats) -> f64 {
    if st.mean_s <= 0.0 {
        return f64::INFINITY;
    }
    rows_per_run as f64 / st.mean_s
}

// ---------------------------------------------------------------------------
// machine-readable perf trajectories (BENCH_<name>.json)
// ---------------------------------------------------------------------------

/// One measurement row of a `BENCH_<name>.json` perf trajectory.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Row label, e.g. `"gemm-nn-1024"` or `"full/N=4096/streaming"`.
    pub name: String,
    pub rows_per_sec: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub iters: usize,
    /// Extra numeric columns (`("gflops", 12.3)`, `("waste", 0.31)`, …).
    pub extra: Vec<(String, f64)>,
}

impl BenchRecord {
    /// Build a record from a timing run over `rows_per_run` rows.
    pub fn from_stats(name: &str, rows_per_run: usize, st: &Stats) -> Self {
        Self {
            name: name.to_string(),
            rows_per_sec: rows_per_sec(rows_per_run, st),
            mean_us: st.mean_us(),
            p50_us: st.p50_s * 1e6,
            p99_us: st.p99_s * 1e6,
            iters: st.iters,
            extra: Vec::new(),
        }
    }

    /// Attach an extra numeric column.
    pub fn with(mut self, key: &str, value: f64) -> Self {
        self.extra.push((key.to_string(), value));
        self
    }
}

/// Peak resident set size of this process in bytes (Linux `VmHWM`;
/// 0 when the platform doesn't expose it).  A high-water mark: it only
/// grows, so sample it right after the workload whose peak you want.
pub fn peak_rss_bytes() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines().find(|l| l.starts_with("VmHWM:")).and_then(|l| {
                l.split_whitespace().nth(1)?.parse::<u64>().ok()
            })
        })
        .map(|kb| kb * 1024)
        .unwrap_or(0)
}

/// Write `BENCH_<bench>.json` at the repo root: the machine-readable
/// perf trajectory CI and plotting scripts diff across commits.
///
/// Schema: `{"bench", "peak_rss_bytes", "records": [{"name",
/// "rows_per_sec", "mean_us", "p50_us", "p99_us", "iters", ...extra}]}`.
/// Non-finite values are clamped to 0 so the output is always valid
/// JSON.  Returns the path written, or `None` on I/O failure (benches
/// must not fail over a read-only checkout).
pub fn write_bench_json(bench: &str,
                        records: &[BenchRecord]) -> Option<std::path::PathBuf> {
    use crate::jsonio::{obj, Value};
    let finite = |v: f64| if v.is_finite() { v } else { 0.0 };
    let rows: Vec<Value> = records
        .iter()
        .map(|r| {
            let mut pairs: Vec<(&str, Value)> = vec![
                ("name", Value::from(r.name.clone())),
                ("rows_per_sec", Value::from(finite(r.rows_per_sec))),
                ("mean_us", Value::from(finite(r.mean_us))),
                ("p50_us", Value::from(finite(r.p50_us))),
                ("p99_us", Value::from(finite(r.p99_us))),
                ("iters", Value::from(r.iters)),
            ];
            for (k, v) in &r.extra {
                pairs.push((k.as_str(), Value::from(finite(*v))));
            }
            obj(pairs)
        })
        .collect();
    let doc = obj(vec![
        ("bench", Value::from(bench)),
        ("peak_rss_bytes", Value::from(peak_rss_bytes() as f64)),
        ("records", Value::Arr(rows)),
    ]);
    let path = crate::config::find_repo_root()
        .join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, crate::jsonio::to_string(&doc) + "\n").ok()?;
    println!("wrote {}", path.display());
    Some(path)
}

/// Parse a `BENCH_<name>.json` document (the [`write_bench_json`]
/// schema) back into records.  Returns `(bench_name, records)`; unknown
/// extra columns round-trip into [`BenchRecord::extra`].  This is the
/// read half the `ct oracle perf-gate` baseline comparison runs on.
pub fn parse_bench_doc(doc: &crate::jsonio::Value)
                       -> anyhow::Result<(String, Vec<BenchRecord>)> {
    use anyhow::anyhow;
    let bench = doc
        .get("bench")
        .as_str()
        .ok_or_else(|| anyhow!("bench doc: missing \"bench\" name"))?
        .to_string();
    let rows = doc
        .get("records")
        .as_arr()
        .ok_or_else(|| anyhow!("bench doc: missing \"records\" array"))?;
    const FIXED: [&str; 6] =
        ["name", "rows_per_sec", "mean_us", "p50_us", "p99_us", "iters"];
    let mut records = Vec::with_capacity(rows.len());
    for row in rows {
        let name = row
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("bench doc: record missing \"name\""))?;
        let num = |key: &str| row.get(key).as_f64().unwrap_or(0.0);
        let mut rec = BenchRecord {
            name: name.to_string(),
            rows_per_sec: num("rows_per_sec"),
            mean_us: num("mean_us"),
            p50_us: num("p50_us"),
            p99_us: num("p99_us"),
            iters: row.get("iters").as_usize().unwrap_or(0),
            extra: Vec::new(),
        };
        if let Some(obj) = row.as_obj() {
            for (k, v) in obj.iter() {
                if !FIXED.contains(&k.as_str()) {
                    if let Some(n) = v.as_f64() {
                        rec.extra.push((k.clone(), n));
                    }
                }
            }
        }
        records.push(rec);
    }
    Ok((bench, records))
}

/// Read and parse a `BENCH_<name>.json` file — see [`parse_bench_doc`].
pub fn read_bench_json(path: &std::path::Path)
                       -> anyhow::Result<(String, Vec<BenchRecord>)> {
    use anyhow::anyhow;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
    let doc = crate::jsonio::parse(&text)
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    parse_bench_doc(&doc)
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_over_known_samples() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.iters, 3);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.std_s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
    }

    #[test]
    fn bench_runs_at_least_min_iters() {
        let mut count = 0;
        let st = bench(|| count += 1, 2, 5,
                       Duration::from_millis(0), 100);
        assert!(st.iters >= 5);
        assert_eq!(count, st.iters + 2);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("longer"));
    }

    #[test]
    fn rows_per_sec_scales_inversely_with_time() {
        let st = Stats::from_samples(&[0.5]);
        assert!((rows_per_sec(1000, &st) - 2000.0).abs() < 1e-9);
        let zero = Stats::from_samples(&[]);
        assert!(rows_per_sec(1, &zero).is_infinite());
    }

    #[test]
    fn stats_percentiles_nearest_rank() {
        let s = Stats::from_samples(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.p50_s, 3.0);
        assert_eq!(s.p99_s, 5.0);
        let empty = Stats::from_samples(&[]);
        assert_eq!(empty.p50_s, 0.0);
        assert_eq!(empty.p99_s, 0.0);
        let one = Stats::from_samples(&[7.5]);
        assert_eq!(one.p50_s, 7.5);
        assert_eq!(one.p99_s, 7.5);
    }

    #[test]
    fn bench_record_carries_stats_and_extras() {
        let st = Stats::from_samples(&[0.001, 0.003]);
        let r = BenchRecord::from_stats("demo", 100, &st)
            .with("gflops", 1.5);
        assert_eq!(r.name, "demo");
        assert!((r.rows_per_sec - 100.0 / 0.002).abs() < 1e-6);
        assert_eq!(r.iters, 2);
        assert_eq!(r.extra, vec![("gflops".to_string(), 1.5)]);
    }

    #[test]
    fn write_bench_json_roundtrips_through_jsonio() {
        let st = Stats::from_samples(&[0.002]);
        let recs = vec![
            BenchRecord::from_stats("a", 10, &st).with("x", 2.0),
            // non-finite values must be clamped, not break the JSON
            BenchRecord::from_stats("b", 1, &Stats::from_samples(&[])),
        ];
        // the API defines unwritable checkouts as non-fatal (None) —
        // don't fail the suite over them, just skip the roundtrip
        let Some(path) = write_bench_json("selftest", &recs) else {
            eprintln!("SKIP: repo root not writable");
            return;
        };
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::jsonio::parse(&text).expect("valid JSON");
        assert_eq!(doc.get("bench").as_str(), Some("selftest"));
        let rows = doc.get("records").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").as_str(), Some("a"));
        assert_eq!(rows[0].get("x").as_f64(), Some(2.0));
        assert_eq!(rows[1].get("rows_per_sec").as_f64(), Some(0.0));
        // peak RSS is best-effort but must be a number
        assert!(doc.get("peak_rss_bytes").as_f64().is_some());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_doc_roundtrips_through_reader() {
        let st = Stats::from_samples(&[0.004, 0.006]);
        let recs = vec![BenchRecord::from_stats("row-a", 500, &st)
                            .with("waste", 0.25)];
        let Some(path) = write_bench_json("readertest", &recs) else {
            eprintln!("SKIP: repo root not writable");
            return;
        };
        let (bench, parsed) = read_bench_json(&path).unwrap();
        assert_eq!(bench, "readertest");
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].name, "row-a");
        assert!((parsed[0].rows_per_sec - recs[0].rows_per_sec).abs() < 1e-6);
        assert_eq!(parsed[0].iters, 2);
        assert_eq!(parsed[0].extra, vec![("waste".to_string(), 0.25)]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-5).ends_with("µs"));
        assert!(fmt_time(2.5e-2).ends_with("ms"));
        assert!(fmt_time(2.5).ends_with('s'));
    }
}
pub mod traincache;
