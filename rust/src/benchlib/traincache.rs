//! Shared train-or-load cache for the benches.
//!
//! Several benches need the same trained checkpoints (fig. 1 ↔ tables 1/2,
//! fig. 7).  `train_or_load` trains through the HLO driver once, stashes
//! the checkpoint (with its loss curve and timing in `meta`) under
//! `target/checkpoints/`, and reuses it afterwards.
//!
//! Effort is controlled by environment variables so `cargo bench` stays
//! bounded by default while full-scale paper runs remain one env var away:
//!   CT_STEPS        ASR training steps per model   (default 60)
//!   CT_STEPS_COPY   copy-task steps per model      (default 150)
//!   CT_STEPS_GLUE   GLUE-analog steps per model    (default 150)
//!   CT_FULL=1       expand benches to the paper's full variant grids

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::trainer::{train_model, TrainOptions, TrainResult};
use crate::jsonio::{obj, Value};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::Runtime;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn full_grid() -> bool {
    std::env::var("CT_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Train `model` for `steps` (or load the cached checkpoint trained with
/// >= steps).  Returns the checkpoint; its `meta` carries
/// `{steps, wall_seconds, seconds_per_step, curve: [[step, loss]...],
///   val_curve: [[step, val_loss]...]}`.
pub fn train_or_load(rt: &Runtime, model: &str, steps: u64)
                     -> Result<Checkpoint> {
    let cfg = RunConfig::default();
    cfg.ensure_dirs()?;
    let path = cfg.checkpoint_path(model);
    if let Ok(ckpt) = Checkpoint::load(&path) {
        let cached_steps =
            ckpt.meta.get("steps").as_i64().unwrap_or(0) as u64;
        if cached_steps >= steps {
            eprintln!("  [cache] {model} ({cached_steps} steps)");
            return Ok(ckpt);
        }
    }
    eprintln!("  [train] {model} for {steps} steps ...");
    let opts = TrainOptions {
        steps,
        eval_every: (steps / 5).max(20),
        patience: 0,
        eval_batches: 2,
        seed: 0,
        verbose: false,
    };
    let (mut ckpt, result) = train_model(rt, model, &opts)?;
    ckpt.meta = result_meta(steps, &result);
    ckpt.save(&path)?;
    Ok(ckpt)
}

fn result_meta(steps: u64, r: &TrainResult) -> Value {
    let curve = Value::Arr(
        r.losses
            .iter()
            .map(|(s, l)| Value::Arr(vec![Value::Num(*s as f64),
                                          Value::Num(*l as f64)]))
            .collect(),
    );
    let val_curve = Value::Arr(
        r.val_losses
            .iter()
            .map(|(s, l)| Value::Arr(vec![Value::Num(*s as f64),
                                          Value::Num(*l as f64)]))
            .collect(),
    );
    obj(vec![
        ("steps", (steps as i64).into()),
        ("wall_seconds", r.wall_seconds.into()),
        ("seconds_per_step", r.seconds_per_step.into()),
        ("final_loss", (r.final_loss as f64).into()),
        ("best_val_loss", (r.best_val_loss as f64).into()),
        ("curve", curve),
        ("val_curve", val_curve),
    ])
}

/// Mean forward-pass wall time of a compiled program (the paper's fig. 1
/// x-axis), measured over `iters` executions with a real batch.
pub fn forward_time(rt: &Runtime, forward_prog: &str, params: &[f32],
                    iters: usize) -> Result<f64> {
    use crate::coordinator::DataFeed;
    use crate::data::Split;
    use crate::runtime::HostTensor;
    let exe = rt.load(forward_prog)?;
    let p = exe.program.clone();
    let feed = DataFeed::for_program(&p, 0)?;
    let mut inputs = vec![HostTensor::F32(params.to_vec())];
    inputs.extend(feed.forward_inputs(Split::Valid, 0, p.batch_size()));
    inputs.push(HostTensor::scalar_i32(1));
    // warmup (compilation already cached by load)
    exe.run(&inputs)?;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        exe.run(&inputs)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64)
}

/// Evaluate a checkpoint through `forward_prog` and return the task score.
pub fn eval_score(rt: &Runtime, forward_prog: &str, params: &[f32],
                  batches: u64)
                  -> Result<crate::coordinator::trainer::Score> {
    use crate::coordinator::trainer::{forward_eval, score};
    use crate::coordinator::DataFeed;
    use crate::data::Split;
    let prog = rt.program(forward_prog)?.clone();
    let feed = DataFeed::for_program(&prog, 0)?;
    let evals = forward_eval(rt, forward_prog, params, &feed, Split::Test,
                             batches, 0)?;
    score(&prog, &feed, &evals)
}
