//! LSH + Hamming-space K-Means (§3.2.2) — the CPU-native substrate.
//!
//! This is the *paper's original* representation: codes are bit-packed
//! into `u64` words and Hamming distance is `popcount(xor)`, i.e. the
//! `__popc` trick of the reference CUDA kernels.  (The TPU/Pallas side
//! instead uses ±1 matmuls — both designs are tested against each other
//! via the shared semantics: argmin of Hamming distance.)
//!
//! Hashing runs as one `(N×D)·(D×bits)` blocked GEMM followed by sign
//! bit-packing, and the K-Means assignment passes partition points over
//! the `ExecCtx` pool — both bit-identical for any worker count (the
//! compute-core contract, `docs/PERF.md`).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{gemm, Matrix};

/// A set of N B-bit codes, packed LSB-first into `words_per_code` u64s.
#[derive(Debug, Clone)]
pub struct BitCodes {
    pub n: usize,
    pub bits: usize,
    pub words_per_code: usize,
    pub words: Vec<u64>,
}

impl BitCodes {
    pub fn new(n: usize, bits: usize) -> Self {
        let wpc = bits.div_ceil(64);
        Self { n, bits, words_per_code: wpc, words: vec![0; n * wpc] }
    }

    #[inline]
    pub fn code(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_code..(i + 1) * self.words_per_code]
    }

    #[inline]
    pub fn set_bit(&mut self, i: usize, b: usize) {
        self.words[i * self.words_per_code + b / 64] |= 1u64 << (b % 64);
    }

    #[inline]
    pub fn get_bit(&self, i: usize, b: usize) -> bool {
        (self.words[i * self.words_per_code + b / 64] >> (b % 64)) & 1 == 1
    }
}

/// Hamming distance between two packed codes.
#[inline]
pub fn hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum()
}

/// Sign-of-random-projection LSH (Shrivastava & Li style, §3.2.2).
///
/// Projects rows of `x` (N×D) onto `bits` random normal directions and
/// packs the signs.
pub struct Lsh {
    pub bits: usize,
    /// (bits × D) projection directions.
    pub proj: Matrix,
}

impl Lsh {
    pub fn new(dim: usize, bits: usize, rng: &mut Xoshiro256) -> Self {
        Self { bits, proj: Matrix::randn(bits, dim, rng) }
    }

    /// Hash every row of `x`: one `(N×D)·(D×bits)` GEMM followed by
    /// sign bit-packing (sequential; see [`Lsh::hash_ctx`]).
    pub fn hash(&self, x: &Matrix) -> BitCodes {
        self.hash_ctx(x, &ExecCtx::sequential())
    }

    /// [`Lsh::hash`] with rows partitioned over the ctx pool.
    ///
    /// The N·bits separate scalar dots of the seed are one blocked NT
    /// GEMM against the packed projection panels; each worker scores
    /// `gemm::MC`-row blocks through one reused `MC × bits` buffer
    /// (O(block) scratch, not O(N·bits)) and packs the signs into its
    /// disjoint span of the code words.  GEMM bit-determinism makes the
    /// codes identical for any worker count and any row blocking.
    pub fn hash_ctx(&self, x: &Matrix, ctx: &ExecCtx) -> BitCodes {
        assert_eq!(x.cols, self.proj.cols, "dim mismatch");
        let mut codes = BitCodes::new(x.rows, self.bits);
        if x.rows == 0 || self.bits == 0 {
            return codes;
        }
        let bp = gemm::pack_nt(&self.proj);
        let (lda, bits, wpc) = (x.cols, self.bits, codes.words_per_code);
        par_rows(ctx, &mut codes.words, x.rows, wpc, |range, words| {
            let mut scores = vec![0f32; gemm::MC * bits];
            let mut r0 = range.start;
            while r0 < range.end {
                let mc = gemm::MC.min(range.end - r0);
                gemm::gemm_rows(&x.data, lda, &bp,
                                &mut scores[..mc * bits], r0, r0 + mc);
                for r in 0..mc {
                    let woff = (r0 - range.start + r) * wpc;
                    for b in 0..bits {
                        if scores[r * bits + b] >= 0.0 {
                            words[woff + b / 64] |= 1u64 << (b % 64);
                        }
                    }
                }
                r0 += mc;
            }
        });
        codes
    }
}

/// Result of a K-Means run.
#[derive(Debug, Clone)]
pub struct Clustering {
    pub n_clusters: usize,
    /// cluster id per point (N,)
    pub groups: Vec<u32>,
    /// members per cluster
    pub counts: Vec<u32>,
    /// final total Hamming cost
    pub cost: u64,
}

/// Assign every code to its nearest centroid (argmin of Hamming
/// distance, first index on ties) and return the total cost.
///
/// The shared assignment pass of `hamming_kmeans` — both the Lloyd
/// iterations and the final stats pass run exactly this.  Points
/// partition over the ctx pool (each point's argmin is independent and
/// the cost reduction is an exact integer sum), so the result is
/// identical for any worker count.
pub fn assign_nearest(codes: &BitCodes, cent: &[u64], n_clusters: usize,
                      groups: &mut [u32], ctx: &ExecCtx) -> u64 {
    let wpc = codes.words_per_code;
    debug_assert_eq!(cent.len(), n_clusters * wpc);
    debug_assert_eq!(groups.len(), codes.n);
    let total = AtomicU64::new(0);
    par_rows(ctx, groups, codes.n, 1, |range, chunk| {
        let mut local = 0u64;
        for (off, i) in range.enumerate() {
            let code = codes.code(i);
            let mut best = (u32::MAX, 0usize);
            for c in 0..n_clusters {
                let d = hamming(code, &cent[c * wpc..(c + 1) * wpc]);
                if d < best.0 {
                    best = (d, c);
                }
            }
            chunk[off] = best.1 as u32;
            local += best.0 as u64;
        }
        total.fetch_add(local, Ordering::Relaxed);
    });
    total.into_inner()
}

/// K-Means in Hamming space with majority-vote centroid updates.
///
/// Deterministic strided init (matches `ref.init_centroid_codes`).  Empty
/// clusters keep their previous centroid.  `point_mask[i] == false`
/// points are assigned but do not vote (query padding).
pub fn hamming_kmeans(codes: &BitCodes, n_clusters: usize, iters: usize,
                      point_mask: Option<&[bool]>) -> Clustering {
    hamming_kmeans_ctx(codes, n_clusters, iters, point_mask,
                       &ExecCtx::sequential())
}

/// [`hamming_kmeans`] with the assignment passes partitioned over the
/// ctx pool.  Two exact optimizations over the seed loop:
///
///  - **early exit** — when an assignment pass reproduces the previous
///    one, the vote update would recompute identical centroids (same
///    votes; tied and empty-cluster bits keep values they already
///    have), so every remaining iteration is a no-op and the loop
///    stops.  The returned clustering is bit-for-bit the same as
///    running all `iters`.
///  - **counting-sort member gather** — votes accumulate per cluster
///    over a contiguous member list (cluster-major) instead of
///    scattering per point, so the per-cluster bit counters stay
///    cache-hot.
pub fn hamming_kmeans_ctx(codes: &BitCodes, n_clusters: usize, iters: usize,
                          point_mask: Option<&[bool]>, ctx: &ExecCtx)
                          -> Clustering {
    hamming_kmeans_model_ctx(codes, n_clusters, iters, point_mask, ctx).0
}

/// [`hamming_kmeans_ctx`] that also returns the final centroid codes
/// (`n_clusters × words_per_code` packed words) — the piece a KV-cached
/// decode session stores so later steps can assign *new* queries to the
/// frozen clustering ([`assign_nearest`] against these centroids)
/// without re-running Lloyd iterations.
pub fn hamming_kmeans_model_ctx(codes: &BitCodes, n_clusters: usize,
                                iters: usize, point_mask: Option<&[bool]>,
                                ctx: &ExecCtx) -> (Clustering, Vec<u64>) {
    assert!(n_clusters >= 1 && codes.n >= 1);
    let wpc = codes.words_per_code;
    // strided init
    let mut cent: Vec<u64> = Vec::with_capacity(n_clusters * wpc);
    for c in 0..n_clusters {
        let idx = c * codes.n / n_clusters;
        cent.extend_from_slice(codes.code(idx));
    }

    let mut groups = vec![0u32; codes.n];
    // sentinel: a group id that assign_nearest can never produce, so
    // the fixed-point check cannot fire before the first comparison
    let mut prev = vec![u32::MAX; codes.n];
    // set when the loop converges: that assignment ran against the
    // final centroids, so the post-loop pass would recompute it
    let mut converged_cost: Option<u64> = None;
    let voting = |i: usize| point_mask.map_or(true, |m| m[i]);

    // reusable gather + vote scratch, hoisted out of the Lloyd loop
    let mut offs = vec![0usize; n_clusters + 1];
    let mut members: Vec<u32> = Vec::with_capacity(codes.n);
    let mut ones = vec![0u32; codes.bits];

    for _ in 0..iters {
        let cost = assign_nearest(codes, &cent, n_clusters, &mut groups,
                                  ctx);
        if prev == groups {
            // fixed point: the update below would change nothing, and
            // this assignment already is the final one
            converged_cost = Some(cost);
            break;
        }
        // counting-sort gather: voting members, cluster-major
        offs.iter_mut().for_each(|o| *o = 0);
        for i in 0..codes.n {
            if voting(i) {
                offs[groups[i] as usize + 1] += 1;
            }
        }
        for c in 0..n_clusters {
            offs[c + 1] += offs[c];
        }
        members.clear();
        members.resize(offs[n_clusters], 0);
        let mut cursor = offs.clone();
        for i in 0..codes.n {
            if voting(i) {
                let g = groups[i] as usize;
                members[cursor[g]] = i as u32;
                cursor[g] += 1;
            }
        }
        // majority vote per cluster, streaming its contiguous members
        for c in 0..n_clusters {
            let mem = &members[offs[c]..offs[c + 1]];
            if mem.is_empty() {
                continue; // empty cluster keeps its previous centroid
            }
            ones.iter_mut().for_each(|o| *o = 0);
            for &i in mem {
                let code = codes.code(i as usize);
                for (b, one) in ones.iter_mut().enumerate() {
                    *one += ((code[b / 64] >> (b % 64)) & 1) as u32;
                }
            }
            for (b, &one) in ones.iter().enumerate() {
                // votes = ones - zeros = 2·ones - members
                let v = 2 * one as i64 - mem.len() as i64;
                let word = &mut cent[c * wpc + b / 64];
                let mask = 1u64 << (b % 64);
                if v > 0 {
                    *word |= mask;
                } else if v < 0 {
                    *word &= !mask;
                } // v == 0 → keep previous bit
            }
        }
        prev.copy_from_slice(&groups);
    }

    // final assignment + stats through the same shared helper (skipped
    // when the loop already converged on the final centroids)
    let cost = converged_cost.unwrap_or_else(|| {
        assign_nearest(codes, &cent, n_clusters, &mut groups, ctx)
    });
    let mut counts = vec![0u32; n_clusters];
    for &g in &groups {
        counts[g as usize] += 1;
    }
    (Clustering { n_clusters, groups, counts, cost }, cent)
}

/// Euclidean K-Means baseline (plain Lloyd on the raw vectors) — used by
/// the ablation bench to quantify what LSH+Hamming gives up vs. costs.
pub fn euclidean_kmeans(x: &Matrix, n_clusters: usize, iters: usize)
                        -> Clustering {
    let (n, d) = (x.rows, x.cols);
    let mut cent = Matrix::zeros(n_clusters, d);
    for c in 0..n_clusters {
        cent.row_mut(c).copy_from_slice(x.row(c * n / n_clusters));
    }
    let mut groups = vec![0u32; n];
    let mut counts = vec![0u32; n_clusters];
    for _ in 0..iters {
        for i in 0..n {
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..n_clusters {
                let dist: f32 = x
                    .row(i)
                    .iter()
                    .zip(cent.row(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            groups[i] = best.1 as u32;
        }
        let mut sums = Matrix::zeros(n_clusters, d);
        counts.iter_mut().for_each(|c| *c = 0);
        for i in 0..n {
            let g = groups[i] as usize;
            counts[g] += 1;
            crate::tensor::axpy(sums.row_mut(g), 1.0, x.row(i));
        }
        for c in 0..n_clusters {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f32;
                for (dst, src) in cent.row_mut(c).iter_mut()
                    .zip(sums.row(c)) {
                    *dst = src * inv;
                }
            }
        }
    }
    let mut cost_f = 0f64;
    for i in 0..n {
        let g = groups[i] as usize;
        cost_f += x.row(i).iter().zip(cent.row(g))
            .map(|(a, b)| ((a - b) * (a - b)) as f64)
            .sum::<f64>();
    }
    Clustering { n_clusters, groups, counts, cost: cost_f as u64 }
}

/// Cluster queries exactly like the L2 graph: LSH codes → Hamming K-Means.
pub fn cluster_queries(q: &Matrix, n_clusters: usize, bits: usize,
                       iters: usize, rng: &mut Xoshiro256) -> Clustering {
    cluster_queries_ctx(q, n_clusters, bits, iters, rng,
                        &ExecCtx::sequential())
}

/// [`cluster_queries`] with hashing and assignment partitioned over the
/// ctx pool.  The RNG draws (the projection directions) happen before
/// any parallel work, so the clustering is bit-identical for any worker
/// count.
pub fn cluster_queries_ctx(q: &Matrix, n_clusters: usize, bits: usize,
                           iters: usize, rng: &mut Xoshiro256,
                           ctx: &ExecCtx) -> Clustering {
    let lsh = Lsh::new(q.cols, bits, rng);
    let codes = lsh.hash_ctx(q, ctx);
    hamming_kmeans_ctx(&codes, n_clusters, iters, None, ctx)
}

/// Cluster every (batch × head) slice of a batched query tensor.
///
/// Slice `s` draws its LSH projections from `prng::slice_stream(seed, s)`
/// and nothing else, so the result is bit-identical whether the pool runs
/// slices in parallel or `cluster_queries` is called per slice in order.
/// Like `AttentionKernel::solve_batch`, the ctx budget splits between
/// the slice axis and intra-slice hashing/assignment.
pub fn cluster_queries_batch(q: &crate::tensor::batch::BatchMatrix,
                             n_clusters: usize, bits: usize, iters: usize,
                             seed: u64, ctx: &ExecCtx)
                             -> Vec<Clustering> {
    let (outer, inner) = ctx.split_batch(q.slices());
    outer.map_indexed(q.slices(), |s| {
        let mut rng = crate::prng::slice_stream(seed, s as u64);
        cluster_queries_ctx(&q.slice_matrix(s), n_clusters, bits, iters,
                            &mut rng, &inner)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_codes(n: usize, bits: usize, seed: u64) -> BitCodes {
        let mut rng = Xoshiro256::new(seed);
        let mut c = BitCodes::new(n, bits);
        for i in 0..n {
            for b in 0..bits {
                if rng.coin(0.5) {
                    c.set_bit(i, b);
                }
            }
        }
        c
    }

    #[test]
    fn hamming_known() {
        assert_eq!(hamming(&[0b1010], &[0b0110]), 2);
        assert_eq!(hamming(&[u64::MAX, 0], &[0, 0]), 64);
    }

    #[test]
    fn bitcodes_set_get_roundtrip() {
        let mut c = BitCodes::new(3, 100);
        c.set_bit(1, 63);
        c.set_bit(1, 64);
        c.set_bit(2, 99);
        assert!(c.get_bit(1, 63) && c.get_bit(1, 64) && c.get_bit(2, 99));
        assert!(!c.get_bit(0, 63) && !c.get_bit(1, 62));
        assert_eq!(c.words_per_code, 2);
    }

    #[test]
    fn lsh_close_vectors_get_close_codes() {
        let mut rng = Xoshiro256::new(1);
        let lsh = Lsh::new(16, 64, &mut rng);
        let base = Matrix::randn(1, 16, &mut rng);
        let mut near = base.clone();
        for v in &mut near.data {
            *v += 0.01 * rng.normal_f32();
        }
        let far = Matrix::randn(1, 16, &mut rng);
        let cb = lsh.hash(&base);
        let cn = lsh.hash(&near);
        let cf = lsh.hash(&far);
        let dn = hamming(cb.code(0), cn.code(0));
        let df = hamming(cb.code(0), cf.code(0));
        assert!(dn < df, "near {dn} !< far {df}");
    }

    #[test]
    fn kmeans_every_point_assigned_to_nearest_centroid_invariant() {
        // Invariant: after convergence pass, no point is closer to another
        // cluster's members' majority code than to its own... we check the
        // weaker, exact invariant: groups = argmin over final centroids.
        // (hamming_kmeans recomputes the final assignment itself; verify
        // counts/cost consistency instead.)
        let codes = random_codes(200, 63, 2);
        let cl = hamming_kmeans(&codes, 16, 10, None);
        assert_eq!(cl.groups.len(), 200);
        assert_eq!(cl.counts.iter().sum::<u32>(), 200);
        assert!(cl.groups.iter().all(|&g| (g as usize) < 16));
    }

    #[test]
    fn kmeans_cost_not_worse_than_single_iter() {
        let codes = random_codes(300, 63, 3);
        let one = hamming_kmeans(&codes, 10, 1, None);
        let ten = hamming_kmeans(&codes, 10, 10, None);
        assert!(ten.cost <= one.cost, "{} > {}", ten.cost, one.cost);
    }

    #[test]
    fn kmeans_separable_data_is_separated() {
        // two obvious blobs in code space: all-zeros vs all-ones
        let mut codes = BitCodes::new(40, 64);
        for i in 20..40 {
            for b in 0..64 {
                codes.set_bit(i, b);
            }
        }
        let cl = hamming_kmeans(&codes, 2, 5, None);
        let g0 = cl.groups[0];
        assert!(cl.groups[..20].iter().all(|&g| g == g0));
        assert!(cl.groups[20..].iter().all(|&g| g != g0));
        assert_eq!(cl.cost, 0);
    }

    #[test]
    fn euclidean_kmeans_separates_blobs() {
        let mut rng = Xoshiro256::new(4);
        let mut x = Matrix::zeros(60, 8);
        for i in 0..60 {
            let center = if i < 30 { 5.0 } else { -5.0 };
            for c in 0..8 {
                x.set(i, c, center + 0.1 * rng.normal_f32());
            }
        }
        let cl = euclidean_kmeans(&x, 2, 5);
        let g0 = cl.groups[0];
        assert!(cl.groups[..30].iter().all(|&g| g == g0));
        assert!(cl.groups[30..].iter().all(|&g| g != g0));
    }

    #[test]
    fn masked_points_do_not_vote() {
        // one far outlier that is masked: centroid should ignore it
        let mut codes = BitCodes::new(10, 16);
        for b in 0..16 {
            codes.set_bit(9, b); // outlier all-ones
        }
        let mask: Vec<bool> =
            (0..10).map(|i| i != 9).collect();
        let cl = hamming_kmeans(&codes, 1, 3, Some(&mask));
        // centroid must be all zeros ⇒ cost = only the outlier's 16 bits
        assert_eq!(cl.cost, 16);
    }

    #[test]
    fn cluster_queries_pipeline_runs() {
        let mut rng = Xoshiro256::new(7);
        let q = Matrix::randn(128, 16, &mut rng);
        let cl = cluster_queries(&q, 8, 31, 5, &mut rng);
        assert_eq!(cl.groups.len(), 128);
        assert_eq!(cl.counts.iter().sum::<u32>(), 128);
    }

    #[test]
    fn gemm_hash_parallel_matches_sequential_bit_for_bit() {
        use crate::exec::WorkerPool;
        let mut rng = Xoshiro256::new(21);
        let lsh = Lsh::new(24, 100, &mut rng); // 2 words per code
        let x = Matrix::randn(137, 24, &mut rng); // ragged row count
        let seq = lsh.hash(&x);
        for workers in [2, 3, 8] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            let par = lsh.hash_ctx(&x, &ctx);
            assert_eq!(par.words, seq.words, "workers={workers}");
        }
        // packing invariant: no bit above `bits` is ever set
        for i in 0..seq.n {
            for b in seq.bits..seq.words_per_code * 64 {
                assert!(!seq.get_bit(i, b), "stray bit {b} in code {i}");
            }
        }
    }

    #[test]
    fn kmeans_parallel_assignment_matches_sequential_bit_for_bit() {
        use crate::exec::WorkerPool;
        let codes = random_codes(211, 63, 9);
        let seq = hamming_kmeans(&codes, 7, 10, None);
        for workers in [2, 5] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            let par = hamming_kmeans_ctx(&codes, 7, 10, None, &ctx);
            assert_eq!(par.groups, seq.groups, "workers={workers}");
            assert_eq!(par.counts, seq.counts);
            assert_eq!(par.cost, seq.cost);
        }
    }

    #[test]
    fn kmeans_early_exit_is_exact_not_approximate() {
        // a run capped at many iterations must equal a run with few when
        // the few already converge — the early exit is a fixed-point
        // detection, not a tolerance
        let codes = random_codes(160, 31, 12);
        let short = hamming_kmeans(&codes, 6, 25, None);
        let long = hamming_kmeans(&codes, 6, 1000, None);
        assert_eq!(short.groups, long.groups);
        assert_eq!(short.cost, long.cost);
    }

    #[test]
    fn assign_nearest_is_the_scalar_argmin() {
        let codes = random_codes(90, 63, 4);
        let cent_src = random_codes(5, 63, 5);
        let cent = cent_src.words.clone();
        let mut groups = vec![0u32; codes.n];
        let cost = assign_nearest(&codes, &cent, 5, &mut groups,
                                  &ExecCtx::sequential());
        let mut want_cost = 0u64;
        for i in 0..codes.n {
            let mut best = (u32::MAX, 0usize);
            for c in 0..5 {
                let d = hamming(codes.code(i), cent_src.code(c));
                if d < best.0 {
                    best = (d, c);
                }
            }
            assert_eq!(groups[i], best.1 as u32, "point {i}");
            want_cost += best.0 as u64;
        }
        assert_eq!(cost, want_cost);
    }

    #[test]
    fn kmeans_model_centroids_reproduce_the_final_assignment() {
        // the returned centroids must be exactly the ones the final
        // assignment ran against: assign_nearest over them reproduces
        // groups and cost bit-for-bit
        let codes = random_codes(150, 63, 17);
        let (cl, cent) = hamming_kmeans_model_ctx(
            &codes, 6, 10, None, &ExecCtx::sequential());
        assert_eq!(cent.len(), 6 * codes.words_per_code);
        let mut groups = vec![0u32; codes.n];
        let cost = assign_nearest(&codes, &cent, 6, &mut groups,
                                  &ExecCtx::sequential());
        assert_eq!(groups, cl.groups);
        assert_eq!(cost, cl.cost);
        // and the plain entry point is the model entry point minus cent
        let plain = hamming_kmeans(&codes, 6, 10, None);
        assert_eq!(plain.groups, cl.groups);
        assert_eq!(plain.cost, cl.cost);
    }

    #[test]
    fn batched_clustering_matches_per_slice_sequential() {
        use crate::exec::WorkerPool;
        use crate::tensor::batch::BatchMatrix;

        let mut rng = Xoshiro256::new(8);
        let q = BatchMatrix::randn(2, 3, 48, 8, &mut rng);
        let par = cluster_queries_batch(
            &q, 4, 31, 5, 9,
            &ExecCtx::with_par_rows(WorkerPool::new(4), 1));
        assert_eq!(par.len(), 6);
        for s in 0..q.slices() {
            let mut rng_s = crate::prng::slice_stream(9, s as u64);
            let want = cluster_queries(&q.slice_matrix(s), 4, 31, 5,
                                       &mut rng_s);
            assert_eq!(par[s].groups, want.groups, "slice {s}");
            assert_eq!(par[s].counts, want.counts, "slice {s}");
            assert_eq!(par[s].cost, want.cost, "slice {s}");
        }
    }
}
