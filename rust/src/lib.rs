//! # clustered-transformers
//!
//! A production-style reproduction of **"Fast Transformers with Clustered
//! Attention"** (Vyas, Katharopoulos, Fleuret — NeurIPS 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the attention
//!   hot-spots, proven against a pure-jnp oracle.
//! - **L2** (`python/compile/`): the transformer model, losses and Adam,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! - **L3** (this crate): the coordinator — PJRT runtime, length-bucketing
//!   router, dynamic batcher, training driver, serving server, metrics —
//!   plus every substrate the experiments need (clustering, reference
//!   attention, synthetic corpora, PRNG, JSON, bench harness).
//!
//! ## The batched multi-head attention engine
//!
//! The Rust reference attention is a **trait-based, batched, multi-head
//! engine** (see `docs/ARCHITECTURE.md` for the full design):
//!
//! - [`attention::AttnProblem`] / [`attention::AttnBatch`] — the
//!   request descriptors every kernel entry point takes: Q/K/V views
//!   plus per-request options (valid-length masks, seeding, the
//!   incremental `query_span`, the autoregressive `causal` flag, and
//!   KV-cache handles
//!   [`attention::CacheRef`] / [`attention::SessionRef`]).  The
//!   **masking contract**: solving bucket-padded inputs with
//!   `valid_len`/`lens` set is bit-identical to solving the unpadded
//!   inputs, and padded output rows are zero.  The **span contract**:
//!   `query_span = s` emits rows `s..valid` bit-identical to the
//!   spanless solve — the incremental-decode primitive.
//! - [`attention::AttentionKernel`] — one algorithm (full, clustered,
//!   improved-clustered, oracle-top, LSH, linear), one file per family
//!   under `attention/`, resolvable by paper-notation name through the
//!   name-keyed [`attention::REGISTRY`] (e.g. `"i-clustered-100"`).
//!   The kernelized [`attention::LinearAttention`] family is the only
//!   one that accepts causal problems: causal linear attention is an
//!   RNN whose constant-size hidden state
//!   ([`attention::RecurrentState`], one `(S: Dk×Dv, z: Dk)`
//!   accumulator per head) the cache layer persists per session, so a
//!   decode step costs O(m·D²) *independent of history length*.
//! - [`attention::AttentionBackend`] — the execution seam over
//!   descriptors: [`attention::NativeBackend`] plus
//!   [`attention::CachingBackend`], which wraps any backend with a
//!   per-session [`attention::KvCache`] so decode steps solve only
//!   their new rows — bit-identical to the full unpadded recompute of
//!   the history, hits and misses alike (causal linear sessions pin a
//!   `RecurrentState` accumulator instead of O(len) panels); and
//!   [`attention::ShardedBackend`], the multi-host fan-out that splits
//!   a descriptor across TCP shard workers (`ct shard-worker`), routes
//!   decode sessions by consistent hash ([`coordinator::HashRing`])
//!   and reassembles outputs bit-identically to the native engine —
//!   compiled-HLO backends plug in behind the same seam.
//! - [`tensor::batch::BatchMatrix`] — a (B, H, N, D) tensor stored as
//!   B·H stacked row-major slices with zero-copy per-slice views
//!   (including ragged `slice_valid` prefixes); slice `s = b·H + h` is
//!   the unit of parallelism.
//! - [`exec::pool::WorkerPool`] — a scoped, std-only worker pool that
//!   maps kernels over (batch × head) slices.  Each slice draws
//!   randomness only from [`prng::slice_stream`]`(seed, s)`, so parallel
//!   output is **bit-identical** to the sequential loop
//!   ([`attention::solve_batch_seq`]) — property-tested in
//!   `proptest/attention_props.rs`.
//! - [`tensor::gemm`] + [`exec::ExecCtx`] — the tiled parallel compute
//!   core (PR 3): cache-blocked panel-packed GEMM, streaming
//!   online-max softmax (full attention never materialises N×N),
//!   one-shot GEMM LSH hashing.  Intra-slice ops partition output rows
//!   over the ctx pool and never split a reduction, so they are
//!   bit-identical for any worker count too (see `docs/PERF.md`).
//! - [`coordinator::NativeAttentionEngine`] — the serving path for the
//!   native kernels: ingress queue → deadline batcher → one descriptor
//!   executed through the backend seam per flush over the pool, with
//!   the same backpressure and metrics as the compiled-HLO
//!   [`coordinator::InferenceEngine`].
//! - [`coordinator::ServingGateway`] — a fleet of those engines, one per
//!   sequence-length [`coordinator::Bucket`], behind the length router:
//!   requests are routed to the tightest bucket, padded, co-batched and
//!   executed over one shared [`exec::SharedWorkerPool`] budget, with
//!   route-up admission control and valid-length masking on by default
//!   — every response is bit-identical to the unpadded computation of
//!   its request, and per-bucket metrics report memory-padding and
//!   masked-compute waste separately.  Decode sessions
//!   ([`coordinator::ServingGateway::submit_session`]) serve
//!   autoregressive traffic through a gateway-global KV cache: pinned
//!   to their bucket, routed up as the history grows, replying with
//!   only the new rows (see `docs/SERVING.md`).
//! - [`oracle`] — the golden-trace regression oracle over all of the
//!   above: `ct oracle record` freezes the gateway's bit-exact outputs
//!   and deterministic counters for a seeded trace suite into
//!   checked-in fixtures, `ct oracle replay` diffs the current build
//!   against them under `oracle/tolerance-policy.json`, and the perf
//!   gate compares fresh `BENCH_*.json` drops against
//!   `bench-baselines/` (see `docs/TESTING.md`).
//!
//! ## Serving in five lines
//!
//! ```
//! use clustered_transformers::coordinator::{Bucket, GatewayOptions,
//!                                           GatewayShape, ServingGateway};
//!
//! let shape = GatewayShape { heads: 1, dk: 4, dv: 4 };
//! let gw = ServingGateway::start(
//!     shape,
//!     vec![Bucket::native("full", 8, 2), Bucket::native("full", 16, 2)],
//!     GatewayOptions::default(),
//! ).unwrap();
//! // a 5-row request routes to the N=8 bucket and is padded to 8 rows;
//! // masking (default) keeps the padded rows out of the math entirely
//! let (q, k, v) = (vec![0.1; 5 * 4], vec![0.2; 5 * 4], vec![0.3; 5 * 4]);
//! let rx = gw.submit_blocking(q, k, v, 5).unwrap();
//! let resp = rx.recv().unwrap();
//! assert_eq!(resp.bucket_seq_len, 8);
//! assert_eq!(resp.out.len(), 5 * 4); // only the valid rows come back
//! assert!(resp.masked); // and they equal the unpadded computation
//! gw.shutdown();
//! ```
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.  Offline builds resolve `anyhow`/`log`/`xla`
//! to the std-only shims under `vendor/`; swapping `vendor/xla` for the
//! real xla_extension bindings re-enables PJRT execution unchanged.
//!
//! - [`lint`] — `ct lint`, the contract-aware static-analysis pass:
//!   a std-only source scanner that mechanically enforces the
//!   invariants above (bit-determinism in `attention`/`tensor`/`exec`,
//!   panic-free serving paths, the wire-field allowlist, registry/doc
//!   agreement), with reasoned `// ct-lint: allow(…)` suppressions and
//!   a byte-stable `lint-report.json` (see `docs/TESTING.md`).
//!
//! See `README.md` for the quickstart and doc map, `DESIGN.md` for the
//! system inventory and experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

// The serving/kernel contracts are machine-checked by `ct lint`
// (release-blocking in CI); the compiler surface backs it up: no
// unsafe anywhere in the crate, and public items are expected to be
// documented (warn-level while the pre-attr surface is back-filled —
// the docs CI job ratchets it).
#![deny(unsafe_code)]
#![warn(missing_docs)]

pub mod attention;
pub mod benchlib;
pub mod cli;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod jsonio;
pub mod lint;
pub mod metrics;
pub mod oracle;
pub mod prng;
pub mod proptest;
pub mod runtime;
pub mod server;
pub mod tensor;
