//! # clustered-transformers
//!
//! A production-style reproduction of **"Fast Transformers with Clustered
//! Attention"** (Vyas, Katharopoulos, Fleuret — NeurIPS 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** (`python/compile/kernels/`): Pallas kernels for the attention
//!   hot-spots, proven against a pure-jnp oracle.
//! - **L2** (`python/compile/`): the transformer model, losses and Adam,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! - **L3** (this crate): the coordinator — PJRT runtime, length-bucketing
//!   router, dynamic batcher, training driver, serving server, metrics —
//!   plus every substrate the experiments need (clustering, reference
//!   attention, synthetic corpora, PRNG, JSON, bench harness).
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! See `DESIGN.md` for the system inventory and experiment index and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod attention;
pub mod benchlib;
pub mod cli;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod jsonio;
pub mod metrics;
pub mod prng;
pub mod proptest;
pub mod runtime;
pub mod server;
pub mod tensor;
