//! JSON-lines-over-TCP inference server + client.
//!
//! Protocol: one JSON object per line.
//!   request : {"id": 1, "frames": [f32...], "len": N, "d_feat": D}
//!   response: {"id": 1, "labels": [i32...], "latency_us": 1234}
//!   error   : {"id": 1, "error": "..."}
//!
//! The server is a thin shim over [`InferenceEngine`]; decoding (greedy
//! CTC) happens server-side so clients receive label sequences.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::InferenceEngine;
use crate::data::asr::ctc_greedy_decode;
use crate::jsonio::{obj, parse, Value};

/// Serve until `stop` flips; returns the bound address immediately via
/// the callback (port 0 = ephemeral).
pub fn serve(engine: Arc<InferenceEngine>, addr: &str,
             stop: Arc<AtomicBool>,
             on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("connection from {peer}");
                let engine = engine.clone();
                // detached: a handler exits when its client disconnects,
                // so shutdown never blocks on open-but-idle connections
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, engine) {
                        log::debug!("conn ended: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, engine: Arc<InferenceEngine>)
               -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_request(&line, &engine) {
            Ok(v) => v,
            Err(e) => obj(vec![("error", format!("{e:#}").into())]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn handle_request(line: &str, engine: &InferenceEngine) -> Result<Value> {
    let req = parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let id = req.get("id").as_i64().unwrap_or(0);
    let len = req
        .get("len")
        .as_usize()
        .ok_or_else(|| anyhow!("missing len"))?;
    let d_feat = req
        .get("d_feat")
        .as_usize()
        .ok_or_else(|| anyhow!("missing d_feat"))?;
    let frames: Vec<f32> = req
        .get("frames")
        .as_arr()
        .ok_or_else(|| anyhow!("missing frames"))?
        .iter()
        .map(|v| v.as_f64().unwrap_or(0.0) as f32)
        .collect();
    if frames.len() != len * d_feat {
        return Err(anyhow!("frames len {} != len*d_feat {}", frames.len(),
                           len * d_feat));
    }
    let rx = engine.submit_blocking(frames, len, d_feat)?;
    let resp = rx
        .recv()
        .map_err(|_| anyhow!("engine dropped the request"))?;
    let labels =
        ctc_greedy_decode(&resp.logits, resp.valid_len, resp.vocab);
    Ok(obj(vec![
        ("id", id.into()),
        ("labels", Value::Arr(
            labels.into_iter().map(|l| Value::Num(l as f64)).collect())),
        ("latency_us",
         ((resp.total_time.as_micros() as i64)).into()),
        ("batch_occupancy", (resp.batch_occupancy as i64).into()),
    ]))
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self { reader: BufReader::new(stream.try_clone()?),
                  writer: stream })
    }

    /// Send one utterance, wait for its decode.
    pub fn transcribe(&mut self, id: i64, frames: &[f32], len: usize,
                      d_feat: usize) -> Result<Value> {
        let frames_json = Value::Arr(
            frames.iter().map(|&f| Value::Num(f as f64)).collect());
        let req = obj(vec![
            ("id", id.into()),
            ("frames", frames_json),
            ("len", len.into()),
            ("d_feat", d_feat.into()),
        ]);
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = parse(&line).map_err(|e| anyhow!("bad reply: {e}"))?;
        if let Some(err) = v.get("error").as_str() {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(v)
    }
}
