//! ct-contract: panic-free
//!
//! JSON-lines-over-TCP inference server + client.
//!
//! Protocol: one JSON object per line.  Two endpoints share the framing:
//!
//! ASR decode ([`serve`], over [`InferenceEngine`]):
//!   request : {"id": 1, "frames": [f32...], "len": N, "d_feat": D}
//!   response: {"id": 1, "labels": [i32...], "latency_us": 1234}
//!
//! Native attention ([`serve_gateway`], over [`ServingGateway`]):
//!   request : {"id": 1, "len": N, "q": [f32...], "k": [...], "v": [...],
//!              "session": 7}          // optional: decode-session step
//!   response: {"id": 1, "out": [f32...], "bucket_n": 128,
//!              "masked": true, "latency_us": 1234,
//!              "batch_occupancy": 3,
//!              "session": 7, "span_start": 96, "cached": true}
//!
//! `len` is the request's true (valid) length: the gateway pads the
//! tensors up to its bucket and, with masking on (the default), `out`
//! is bit-identical to computing the unpadded request — `"masked":
//! true` in the response asserts exactly that.  `"masked": false`
//! means the gateway was started with static-shape semantics
//! (`GatewayOptions { mask: false, … }`) and padded keys participated.
//!
//! With `"session"` set, the request is one step of an incremental
//! decode session: the tensors carry the session's *full history* (len
//! grows every step), the reply's `out` holds only the new rows
//! (`span_start..len`), and `"cached": true` means the KV cache held
//! the prefix so only the span was computed (`false` = transparent
//! full-recompute fallback; the bits are identical either way).  The
//! session/span/cached fields are absent on one-shot replies.
//! `{"id": 9, "session": 7, "end": true}` ends a session — replied
//! with `{"id": 9, "session": 7, "ended": true, "was_live": true}` —
//! releasing its gateway state and cached panels.  `end` is
//! idempotent: unknown sessions and duplicate ends succeed with
//! `"was_live": false` and create no state.
//!
//! Either endpoint replies {"id": ..., "error": "..."} on a bad request
//! (including backpressure surfaced from the engine; `id` is 0 when the
//! line was not valid JSON).  Decoding (greedy CTC) happens server-side
//! on the ASR endpoint so clients receive label sequences.
//!
//! Shard worker ([`serve_shard_worker`], over
//! `attention::sharded::ShardEngine`, the `ct shard-worker` endpoint):
//! unlike the two JSON-tensor endpoints above, this one is on the
//! sharded fan-out hot path, so tensors travel as **raw little-endian
//! f32 frames** after a JSON header line — never JSON-encoded.  A
//! `"solve"` header (dims, kernel, hex seed/slice_base, optional
//! lens/session) is followed by the q, k, v frames; the reply header
//! (`"ok": true`, dims, optional `"outcome"`) is followed by the
//! output frame.  `"ping"` and `"end"` ops are header-only.  Framing
//! recovery rule: a header that fails to parse closes the connection
//! (the frame boundary is unknowable), while an engine error *after*
//! the frames were consumed replies `{"id", "error"}` and keeps
//! serving.  See `attention::sharded` for the full wire grammar.

// The panic-free serving contract, compiler-side: `ct lint` scans the
// source, clippy guards what the scanner cannot see through macros.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::attention::sharded::{cache_stats_to_value, outcome_to_value,
                                parse_hex_u64, read_f32s, write_f32s,
                                ShardEngine, ShardRequest, SolveHeader};
use crate::coordinator::{InferenceEngine, ServingGateway};
use crate::data::asr::ctc_greedy_decode;
use crate::jsonio::{obj, parse, Value};
use crate::tensor::batch::BatchMatrix;

/// Accept connections until `stop` flips, spawning one detached handler
/// thread per connection; reports the bound address via `on_bound`
/// (port 0 = ephemeral).
fn accept_loop<H>(addr: &str, stop: Arc<AtomicBool>,
                  on_bound: impl FnOnce(std::net::SocketAddr),
                  handler: H) -> Result<()>
where
    H: Fn(TcpStream) -> Result<()> + Send + Sync + 'static,
{
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let handler = Arc::new(handler);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("connection from {peer}");
                let handler = handler.clone();
                // detached: a handler exits when its client disconnects,
                // so shutdown never blocks on open-but-idle connections
                std::thread::spawn(move || {
                    if let Err(e) = (handler.as_ref())(stream) {
                        log::debug!("conn ended: {e:#}");
                    }
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// One request/reply line-loop over `stream`: each line parses to JSON,
/// goes through `reply_for`, and any failure becomes an `{"id", "error"}`
/// object keyed to the request it belongs to.
fn line_loop(stream: TcpStream,
             reply_for: impl Fn(&Value) -> Result<Value>) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse(&line) {
            Err(e) => obj(vec![
                ("id", 0i64.into()),
                ("error", format!("bad json: {e}").into()),
            ]),
            Ok(req) => {
                let id = req.get("id").as_i64().unwrap_or(0);
                match reply_for(&req) {
                    Ok(v) => v,
                    Err(e) => obj(vec![
                        ("id", id.into()),
                        ("error", format!("{e:#}").into()),
                    ]),
                }
            }
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Serve the ASR decode endpoint until `stop` flips.
pub fn serve(engine: Arc<InferenceEngine>, addr: &str,
             stop: Arc<AtomicBool>,
             on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
    accept_loop(addr, stop, on_bound, move |stream| {
        let engine = engine.clone();
        line_loop(stream, move |req| handle_request(req, &engine))
    })
}

/// Serve the native attention gateway endpoint until `stop` flips.
pub fn serve_gateway(gateway: Arc<ServingGateway>, addr: &str,
                     stop: Arc<AtomicBool>,
                     on_bound: impl FnOnce(std::net::SocketAddr))
                     -> Result<()> {
    accept_loop(addr, stop, on_bound, move |stream| {
        let gateway = gateway.clone();
        line_loop(stream, move |req| handle_attn_request(req, &gateway))
    })
}

/// Serve the shard-worker endpoint (binary-framed `AttnBatch` slices
/// for `attention::ShardedBackend`) until `stop` flips.
pub fn serve_shard_worker(engine: Arc<ShardEngine>, addr: &str,
                          stop: Arc<AtomicBool>,
                          on_bound: impl FnOnce(std::net::SocketAddr))
                          -> Result<()> {
    accept_loop(addr, stop, on_bound, move |stream| {
        shard_conn_loop(stream, &engine)
    })
}

fn reply_line(w: &mut TcpStream, v: Value) -> Result<()> {
    w.write_all(v.to_string().as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

/// One shard-worker connection: JSON header lines with raw f32 frames
/// between them.  The loop's framing discipline is the whole game — a
/// header we cannot parse means we no longer know where the next frame
/// boundary is, so the connection closes after one error reply; an
/// engine failure after the frames were read leaves the stream in sync,
/// so the connection survives it.
fn shard_conn_loop(stream: TcpStream, engine: &Arc<ShardEngine>)
                   -> Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // clean disconnect
        }
        if line.trim().is_empty() {
            continue;
        }
        let req = match parse(&line) {
            Ok(v) => v,
            Err(e) => {
                // frame boundary unknowable from here: reply, close
                reply_line(&mut writer, obj(vec![
                    ("id", 0i64.into()),
                    ("error", format!("bad json: {e}").into()),
                ]))?;
                return Ok(());
            }
        };
        let id = req.get("id").as_i64().unwrap_or(0);
        match req.get("op").as_str() {
            Some("ping") => {
                reply_line(&mut writer, obj(vec![
                    ("id", id.into()),
                    ("ok", true.into()),
                ]))?;
            }
            Some("end") => match parse_hex_u64(req.get("session")) {
                Ok(sid) => {
                    engine.end_session(sid);
                    reply_line(&mut writer, obj(vec![
                        ("id", id.into()),
                        ("ok", true.into()),
                    ]))?;
                }
                Err(e) => {
                    reply_line(&mut writer, obj(vec![
                        ("id", id.into()),
                        ("error", format!("{e:#}").into()),
                    ]))?;
                }
            },
            Some("solve") => {
                let hdr = match SolveHeader::parse(&req) {
                    Ok(h) => h,
                    Err(e) => {
                        // the peer is about to stream frames we cannot
                        // size: reply, close
                        reply_line(&mut writer, obj(vec![
                            ("id", id.into()),
                            ("error", format!("{e:#}").into()),
                        ]))?;
                        return Ok(());
                    }
                };
                let (Some(nqk), Some(nv)) =
                    (hdr.payload_elems(hdr.dk), hdr.payload_elems(hdr.dv))
                else {
                    reply_line(&mut writer, obj(vec![
                        ("id", hdr.id.into()),
                        ("error", "payload too large".into()),
                    ]))?;
                    return Ok(());
                };
                let q = read_f32s(&mut reader, nqk)?;
                let k = read_f32s(&mut reader, nqk)?;
                let v = read_f32s(&mut reader, nv)?;
                let shard_req = ShardRequest {
                    kernel: hdr.kernel.clone(),
                    q: BatchMatrix::from_vec(hdr.batch, hdr.heads,
                                             hdr.rows, hdr.dk, q),
                    k: BatchMatrix::from_vec(hdr.batch, hdr.heads,
                                             hdr.rows, hdr.dk, k),
                    v: BatchMatrix::from_vec(hdr.batch, hdr.heads,
                                             hdr.rows, hdr.dv, v),
                    seed: hdr.seed,
                    slice_base: hdr.slice_base,
                    lens: hdr.lens.clone(),
                    causal: hdr.causal,
                    cache_quant: hdr.cache_quant,
                    session: hdr.session,
                };
                match engine.solve(&shard_req) {
                    Ok(rep) => {
                        let mut fields = vec![
                            ("id", hdr.id.into()),
                            ("ok", true.into()),
                            ("batch", rep.out.batch.into()),
                            ("heads", rep.out.heads.into()),
                            ("rows", rep.out.rows.into()),
                            ("cols", rep.out.cols.into()),
                        ];
                        if let Some(oc) = &rep.outcome {
                            fields.push(("outcome", outcome_to_value(oc)));
                        }
                        if let Some(c) = &rep.cache {
                            // optional counter snapshot: plain replies
                            // omit it and stay byte-stable
                            fields.push(("cache",
                                         cache_stats_to_value(c)));
                        }
                        reply_line(&mut writer, obj(fields))?;
                        write_f32s(&mut writer, &rep.out.data)?;
                        writer.flush()?;
                    }
                    // frames consumed: the stream is in sync, keep it
                    Err(e) => {
                        reply_line(&mut writer, obj(vec![
                            ("id", hdr.id.into()),
                            ("error", format!("{e:#}").into()),
                        ]))?;
                    }
                }
            }
            other => {
                reply_line(&mut writer, obj(vec![
                    ("id", id.into()),
                    ("error", format!("unknown op {other:?}").into()),
                ]))?;
            }
        }
    }
}

fn f32_field(req: &Value, key: &str) -> Result<Vec<f32>> {
    Ok(req
        .get(key)
        .as_arr()
        .ok_or_else(|| anyhow!("missing {key}"))?
        .iter()
        .map(|v| v.as_f64().unwrap_or(0.0) as f32)
        .collect())
}

fn handle_request(req: &Value, engine: &InferenceEngine) -> Result<Value> {
    let id = req.get("id").as_i64().unwrap_or(0);
    let len = req
        .get("len")
        .as_usize()
        .ok_or_else(|| anyhow!("missing len"))?;
    let d_feat = req
        .get("d_feat")
        .as_usize()
        .ok_or_else(|| anyhow!("missing d_feat"))?;
    let frames = f32_field(req, "frames")?;
    if frames.len() != len * d_feat {
        return Err(anyhow!("frames len {} != len*d_feat {}", frames.len(),
                           len * d_feat));
    }
    let rx = engine.submit_blocking(frames, len, d_feat)?;
    let resp = rx
        .recv()
        .map_err(|_| anyhow!("engine dropped the request"))?;
    let labels =
        ctc_greedy_decode(&resp.logits, resp.valid_len, resp.vocab);
    Ok(obj(vec![
        ("id", id.into()),
        ("labels", Value::Arr(
            labels.into_iter().map(|l| Value::Num(l as f64)).collect())),
        ("latency_us",
         ((resp.total_time.as_micros() as i64)).into()),
        ("batch_occupancy", (resp.batch_occupancy as i64).into()),
    ]))
}

fn handle_attn_request(req: &Value, gateway: &ServingGateway)
                       -> Result<Value> {
    let id = req.get("id").as_i64().unwrap_or(0);
    let session = req.get("session").as_i64().map(|s| s as u64);
    // {"id", "session", "end": true} releases the session's gateway
    // state and cached panels — long-running servers must not leak a
    // table entry per session ever seen
    if req.get("end").as_bool() == Some(true) {
        let sid = session
            .ok_or_else(|| anyhow!("\"end\" needs a \"session\""))?;
        let was_live = gateway.end_session(sid);
        // `ended` is idempotent-success; `was_live` tells a client
        // whether this end actually tore a session down (false for
        // unknown sessions and duplicate ends — both harmless)
        return Ok(obj(vec![
            ("id", id.into()),
            ("session", (sid as i64).into()),
            ("ended", true.into()),
            ("was_live", was_live.into()),
        ]));
    }
    let len = req
        .get("len")
        .as_usize()
        .ok_or_else(|| anyhow!("missing len"))?;
    let (q, k, v) = (f32_field(req, "q")?, f32_field(req, "k")?,
                     f32_field(req, "v")?);
    // blocking: a TCP client rides out backpressure instead of seeing
    // spurious 429-style errors (fail-fast admission is the bench's job)
    let rx = match session {
        Some(sid) => gateway.submit_session_blocking(q, k, v, len, sid)?,
        None => gateway.submit_blocking(q, k, v, len)?,
    };
    let resp = rx
        .recv()
        .map_err(|_| anyhow!("gateway dropped the request"))?;
    let mut fields = vec![
        ("id", id.into()),
        ("out", Value::Arr(
            resp.out.iter().map(|&x| Value::Num(x as f64)).collect())),
        ("bucket_n", (resp.bucket_seq_len as i64).into()),
        ("masked", resp.masked.into()),
        ("latency_us", (resp.total_time.as_micros() as i64).into()),
        ("batch_occupancy", (resp.batch_occupancy as i64).into()),
    ];
    if let Some(sid) = resp.session {
        fields.push(("session", (sid as i64).into()));
        fields.push(("span_start", (resp.span_start as i64).into()));
        fields.push(("cached", resp.cache_hit.unwrap_or(false).into()));
    }
    Ok(obj(fields))
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self { reader: BufReader::new(stream.try_clone()?),
                  writer: stream })
    }

    fn round_trip(&mut self, req: Value) -> Result<Value> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = parse(&line).map_err(|e| anyhow!("bad reply: {e}"))?;
        if let Some(err) = v.get("error").as_str() {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(v)
    }

    /// Send one utterance to the ASR endpoint, wait for its decode.
    pub fn transcribe(&mut self, id: i64, frames: &[f32], len: usize,
                      d_feat: usize) -> Result<Value> {
        let frames_json = Value::Arr(
            frames.iter().map(|&f| Value::Num(f as f64)).collect());
        self.round_trip(obj(vec![
            ("id", id.into()),
            ("frames", frames_json),
            ("len", len.into()),
            ("d_feat", d_feat.into()),
        ]))
    }

    /// Send one (H, len, D) attention request to the gateway endpoint.
    ///
    /// `len` is the request's true valid length — the gateway buckets
    /// and pads internally, and with masking on (the default) the
    /// reply's `out` is bit-identical to computing the unpadded
    /// request (`"masked": true` in the reply confirms it).
    pub fn attend(&mut self, id: i64, q: &[f32], k: &[f32], v: &[f32],
                  len: usize) -> Result<Value> {
        let arr = |xs: &[f32]| Value::Arr(
            xs.iter().map(|&x| Value::Num(x as f64)).collect());
        self.round_trip(obj(vec![
            ("id", id.into()),
            ("len", len.into()),
            ("q", arr(q)),
            ("k", arr(k)),
            ("v", arr(v)),
        ]))
    }

    /// Send one decode-session step: the session's full (H, len, D)
    /// history plus its id.  The reply's `out` carries only the new
    /// rows (`span_start..len`); `cached` reports whether the KV cache
    /// held the prefix (the bits are the same either way).
    pub fn attend_session(&mut self, id: i64, q: &[f32], k: &[f32],
                          v: &[f32], len: usize, session: u64)
                          -> Result<Value> {
        let arr = |xs: &[f32]| Value::Arr(
            xs.iter().map(|&x| Value::Num(x as f64)).collect());
        self.round_trip(obj(vec![
            ("id", id.into()),
            ("len", len.into()),
            ("session", (session as i64).into()),
            ("q", arr(q)),
            ("k", arr(k)),
            ("v", arr(v)),
        ]))
    }

    /// End a decode session: the gateway drops its table entry and
    /// cached panels (a later session under the same id gets a fresh
    /// generation and can never alias the old cache state).
    pub fn end_session(&mut self, id: i64, session: u64) -> Result<Value> {
        self.round_trip(obj(vec![
            ("id", id.into()),
            ("session", (session as i64).into()),
            ("end", true.into()),
        ]))
    }
}
