//! ct-contract: bit-exact
//! ct-lint: allow(det-float-accum, reason = "the GEMM microkernel IS the pinned elementary order: k ascending within a fixed tile schedule, bit-stable for any worker count")
//!
//! Cache-blocked, panel-packed f32 GEMM — the compute core every
//! attention kernel's matmuls run on.
//!
//! Two variants share one register-tiled microkernel over `MR × NR`
//! output tiles:
//!
//!  - **NN** — `a (m×k) · b (k×n)`, the attention `A·V` shape;
//!  - **NT** — `a (m×k) · bᵀ` with `b (n×k)`, the attention-logits
//!    `Q·Kᵀ` shape (and the one-shot LSH projection).
//!
//! The `b` operand is packed once into `NR`-column panels
//! ([`PackedB`]), `a` tiles are packed on the fly into `MR`-row panels,
//! so the microkernel's inner loop is unit-stride on both sides and the
//! k panels stream through L1/L2 ([`KC`] deep, [`MC`]-row blocks).
//!
//! **Determinism contract.**  Every output element is accumulated in
//! strictly increasing `k` order into a single f32 accumulator (carried
//! across k panels through an exact f32 store/reload of the output
//! tile).  Tile shape, panel order and row partitioning therefore never
//! reorder a reduction, and the blocked result is **bit-identical** to
//! the naive i-k-j scalar loops ([`naive_nn`] / [`naive_nt`]) for any
//! shape and any [`ExecCtx`] worker count — property-tested in
//! `proptest/attention_props.rs`.  Parallelism partitions **output rows
//! only** (`exec::par_rows`); the k reduction is never split.

use crate::exec::{par_rows, ExecCtx};
use crate::tensor::Matrix;

/// Microkernel tile height (output rows per register tile).
pub const MR: usize = 4;
/// Microkernel tile width (output columns per register tile).
pub const NR: usize = 8;
/// k-panel depth: one packed a/b panel pair streams through L1.
pub const KC: usize = 256;
/// Output row-block height packed per driver pass.
pub const MC: usize = 64;

/// The `b` operand of a GEMM, packed into `NR`-column panels.
///
/// Layout: k panels (depth ≤ [`KC`]) outermost; within a panel, one
/// `kc × NR` block per `NR`-column group, element `(kk, jj)` at
/// `kk·NR + jj`; ragged edges zero-padded.  Zero padding never changes
/// output bits — padded lanes are never stored — and keeps the
/// microkernel free of bounds checks on the packed side.
pub struct PackedB {
    /// Output columns (b cols for NN, b rows for NT).
    pub n: usize,
    /// Reduction depth.
    pub k: usize,
    /// Column groups: `n.div_ceil(NR)`.
    nb: usize,
    data: Vec<f32>,
}

impl PackedB {
    fn with_layout(n: usize, k: usize) -> Self {
        let nb = n.div_ceil(NR);
        // earlier panels are always full KC deep (panel_off relies on
        // that); the last panel only needs its true kc depth
        let data = vec![0.0; packed_len(k, nb * NR)];
        Self { n, k, nb, data }
    }

    /// Byte layout offset of panel `p` (all earlier panels are full).
    #[inline]
    fn panel_off(&self, p: usize) -> usize {
        p * KC * self.nb * NR
    }
}

/// Packed buffer length for depth `k` and a padded panel width of
/// `width` lanes: full `KC` for every panel but the last, which is
/// sized at its actual depth.
#[inline]
fn packed_len(k: usize, width: usize) -> usize {
    let k_panels = k.div_ceil(KC);
    if k_panels == 0 {
        return 0;
    }
    let kc_last = k - (k_panels - 1) * KC;
    ((k_panels - 1) * KC + kc_last) * width
}

/// Pack `b (k×n)` for the NN product `a · b`.
pub fn pack_nn(b: &Matrix) -> PackedB {
    let (k, n) = (b.rows, b.cols);
    let mut out = PackedB::with_layout(n, k);
    for p in 0..k.div_ceil(KC) {
        let k0 = p * KC;
        let kc = KC.min(k - k0);
        let base = out.panel_off(p);
        for jb in 0..out.nb {
            let boff = base + jb * (kc * NR);
            let j0 = jb * NR;
            let jn = NR.min(n - j0);
            for kk in 0..kc {
                let brow = &b.data[(k0 + kk) * n + j0..];
                for jj in 0..jn {
                    out.data[boff + kk * NR + jj] = brow[jj];
                }
            }
        }
    }
    out
}

/// Pack `b (n×k)` for the NT product `a · bᵀ`.
pub fn pack_nt(b: &Matrix) -> PackedB {
    let (n, k) = (b.rows, b.cols);
    let mut out = PackedB::with_layout(n, k);
    for p in 0..k.div_ceil(KC) {
        let k0 = p * KC;
        let kc = KC.min(k - k0);
        let base = out.panel_off(p);
        for jb in 0..out.nb {
            let boff = base + jb * (kc * NR);
            let j0 = jb * NR;
            let jn = NR.min(n - j0);
            for jj in 0..jn {
                let brow = &b.data[(j0 + jj) * k + k0..];
                for kk in 0..kc {
                    out.data[boff + kk * NR + jj] = brow[kk];
                }
            }
        }
    }
    out
}

/// Pack an `m`-row tile of `a` (row stride `lda`, rows `r0..r0+m`,
/// depth `k`) into `MR`-row panels matching [`PackedB`]'s k-panel
/// layout.  `apack` is caller-owned scratch, reused across tiles.
pub fn pack_a_tile(a: &[f32], lda: usize, r0: usize, m: usize, k: usize,
                   apack: &mut Vec<f32>) {
    let mtiles = m.div_ceil(MR);
    let k_panels = k.div_ceil(KC);
    apack.clear();
    apack.resize(packed_len(k, mtiles * MR), 0.0);
    for p in 0..k_panels {
        let k0 = p * KC;
        let kc = KC.min(k - k0);
        let base = p * KC * mtiles * MR;
        for t in 0..mtiles {
            let toff = base + t * (kc * MR);
            let rn = MR.min(m - t * MR);
            for rr in 0..rn {
                let arow = &a[(r0 + t * MR + rr) * lda + k0..];
                for kk in 0..kc {
                    apack[toff + kk * MR + rr] = arow[kk];
                }
            }
        }
    }
}

/// `MR × NR` register tile: `out[tile] (+)= a_panel · b_panel`.
///
/// `first_panel` selects write vs accumulate; accumulation loads the
/// exact f32 partial sum back, so the per-element add order is strictly
/// increasing k across panels.  Padded lanes compute on zeros and are
/// never stored.
#[inline]
fn microkernel(kc: usize, a_panel: &[f32], b_panel: &[f32],
               out: &mut [f32], c_off: usize, ldc: usize, mr: usize,
               nr: usize, first_panel: bool) {
    let mut acc = [[0f32; NR]; MR];
    if !first_panel {
        for (r, arow) in acc.iter_mut().enumerate().take(mr) {
            let orow = &out[c_off + r * ldc..];
            arow[..nr].copy_from_slice(&orow[..nr]);
        }
    }
    for kk in 0..kc {
        let av = &a_panel[kk * MR..kk * MR + MR];
        let bv = &b_panel[kk * NR..kk * NR + NR];
        for r in 0..MR {
            let ar = av[r];
            for c in 0..NR {
                acc[r][c] += ar * bv[c];
            }
        }
    }
    for (r, arow) in acc.iter().enumerate().take(mr) {
        let orow = &mut out[c_off + r * ldc..];
        orow[..nr].copy_from_slice(&arow[..nr]);
    }
}

/// `out` (an `m × cols` window with row stride `ldc`) = packed-a-tile
/// times columns `j0 .. j0+cols` of `bp`.  `j0` must be `NR`-aligned;
/// the window is overwritten (no pre-zeroing needed).  This is the
/// streaming-softmax inner step: one query tile against one key block.
pub fn tile_mul(apack: &[f32], m: usize, bp: &PackedB, j0: usize,
                cols: usize, out: &mut [f32], ldc: usize) {
    debug_assert_eq!(j0 % NR, 0, "tile_mul j0 must be NR-aligned");
    debug_assert!(j0 + cols <= bp.n, "tile_mul window out of range");
    if m == 0 || cols == 0 {
        return;
    }
    if bp.k == 0 {
        for r in 0..m {
            out[r * ldc..r * ldc + cols].fill(0.0);
        }
        return;
    }
    let mtiles = m.div_ceil(MR);
    let (jb0, jb1) = (j0 / NR, (j0 + cols).div_ceil(NR));
    for p in 0..bp.k.div_ceil(KC) {
        let kc = KC.min(bp.k - p * KC);
        let a_base = p * KC * mtiles * MR;
        let b_base = bp.panel_off(p);
        for jb in jb0..jb1 {
            let jcol = jb * NR;
            let nr = NR.min(bp.n - jcol).min(j0 + cols - jcol);
            let boff = b_base + jb * (kc * NR);
            for t in 0..mtiles {
                let i0 = t * MR;
                let mr = MR.min(m - i0);
                let aoff = a_base + t * (kc * MR);
                microkernel(kc, &apack[aoff..aoff + kc * MR],
                            &bp.data[boff..boff + kc * NR], out,
                            i0 * ldc + (jcol - j0), ldc, mr, nr, p == 0);
            }
        }
    }
}

/// Compute output rows `r0..r1` of `a · bp` into `chunk` (whose row 0 is
/// global row `r0`).  The per-worker driver: `MC`-row blocks, on-the-fly
/// a packing, full output width.
pub fn gemm_rows(a: &[f32], lda: usize, bp: &PackedB, chunk: &mut [f32],
                 r0: usize, r1: usize) {
    let n = bp.n;
    let mut apack = Vec::new();
    let mut ic = r0;
    while ic < r1 {
        let mc = MC.min(r1 - ic);
        pack_a_tile(a, lda, ic, mc, bp.k, &mut apack);
        let base = (ic - r0) * n;
        tile_mul(&apack, mc, bp, 0, n, &mut chunk[base..base + mc * n], n);
        ic += mc;
    }
}

fn run(a: &Matrix, bp: &PackedB, ctx: &ExecCtx) -> Matrix {
    let (m, n) = (a.rows, bp.n);
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    let lda = a.cols;
    par_rows(ctx, &mut out.data, m, n, |range, chunk| {
        gemm_rows(&a.data, lda, bp, chunk, range.start, range.end);
    });
    out
}

/// `a (m×k) · b (k×n)` — blocked, panel-packed, row-partitioned on the
/// ctx pool.  Bit-identical to [`naive_nn`] for any worker count.
pub fn matmul_nn(a: &Matrix, b: &Matrix, ctx: &ExecCtx) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    run(a, &pack_nn(b), ctx)
}

/// `a (m×k) · bᵀ` with `b (n×k)` — the attention-logits shape.
/// Bit-identical to [`naive_nt`] for any worker count.
pub fn matmul_nt(a: &Matrix, b: &Matrix, ctx: &ExecCtx) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    run(a, &pack_nt(b), ctx)
}

/// Reference NN product: the unblocked i-k-j scalar loop (one f32
/// accumulator per element, ascending k) the blocked path must match
/// bit for bit.
pub fn naive_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate().take(k) {
            let brow = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Reference NT product: scalar k-ordered dots (single accumulator per
/// element, matching the blocked accumulation order exactly).
pub fn naive_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut acc = 0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            out.data[i * n + j] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::WorkerPool;
    use crate::prng::Xoshiro256;

    fn bits_eq(a: &Matrix, b: &Matrix) -> bool {
        a.bit_identical(b)
    }

    #[test]
    fn blocked_nn_matches_naive_bit_for_bit_on_ragged_shapes() {
        let mut rng = Xoshiro256::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (MR, KC, NR),
                            (MR + 1, KC + 3, NR + 5), (65, 70, 33),
                            (MC + 9, 2 * KC + 1, 2 * NR + 3)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let blocked = matmul_nn(&a, &b, &ExecCtx::sequential());
            assert!(bits_eq(&blocked, &naive_nn(&a, &b)),
                    "NN diverged at ({m},{k},{n})");
        }
    }

    #[test]
    fn blocked_nt_matches_naive_bit_for_bit_on_ragged_shapes() {
        let mut rng = Xoshiro256::new(2);
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 9), (17, 64, 129),
                            (MC + 1, KC + 7, 2 * NR + 1)] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(n, k, &mut rng);
            let blocked = matmul_nt(&a, &b, &ExecCtx::sequential());
            assert!(bits_eq(&blocked, &naive_nt(&a, &b)),
                    "NT diverged at ({m},{k},{n})");
        }
    }

    #[test]
    fn parallel_rows_never_change_the_bits() {
        let mut rng = Xoshiro256::new(3);
        let a = Matrix::randn(70, 33, &mut rng);
        let b = Matrix::randn(33, 21, &mut rng);
        let bt = Matrix::randn(21, 33, &mut rng);
        let seq_nn = matmul_nn(&a, &b, &ExecCtx::sequential());
        let seq_nt = matmul_nt(&a, &bt, &ExecCtx::sequential());
        for workers in [2, 3, 8] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            assert!(bits_eq(&matmul_nn(&a, &b, &ctx), &seq_nn),
                    "NN workers={workers}");
            assert!(bits_eq(&matmul_nt(&a, &bt, &ctx), &seq_nt),
                    "NT workers={workers}");
        }
    }

    #[test]
    fn tile_mul_window_matches_full_product_columns() {
        let mut rng = Xoshiro256::new(4);
        let (m, k, n) = (11, 40, 48);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(n, k, &mut rng);
        let full = matmul_nt(&a, &b, &ExecCtx::sequential());
        let bp = pack_nt(&b);
        let mut apack = Vec::new();
        pack_a_tile(&a.data, k, 0, m, k, &mut apack);
        // window [16, 16+24): NR-aligned start, ragged width
        let (j0, cols) = (2 * NR, 3 * NR + 1);
        let mut win = vec![f32::NAN; m * cols];
        tile_mul(&apack, m, &bp, j0, cols, &mut win, cols);
        for r in 0..m {
            for c in 0..cols {
                assert_eq!(win[r * cols + c].to_bits(),
                           full.at(r, j0 + c).to_bits(),
                           "({r},{c})");
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_zeros_not_panics() {
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 6);
        let out = matmul_nn(&a, &b, &ExecCtx::sequential());
        assert_eq!((out.rows, out.cols), (4, 6));
        assert!(out.data.iter().all(|&x| x == 0.0));
        let bt = Matrix::zeros(6, 0);
        let out = matmul_nt(&a, &bt, &ExecCtx::sequential());
        assert!(out.data.iter().all(|&x| x == 0.0));
        let empty = matmul_nn(&Matrix::zeros(0, 3), &Matrix::zeros(3, 2),
                              &ExecCtx::sequential());
        assert_eq!((empty.rows, empty.cols), (0, 2));
    }

    #[test]
    fn known_small_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul_nn(&a, &b, &ExecCtx::sequential());
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }
}
