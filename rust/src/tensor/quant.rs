//! ct-contract: tolerance-gated
//!
//! Symmetric i8 quantization for cached K/V panels — the storage side
//! of the quantized KV cache ([`crate::attention::KvCache`] with
//! `quant != Off`).
//!
//! ## Scaling scheme
//!
//! Every quantized segment stores `round(x / scale)` clamped to
//! `[-127, 127]` as `i8`, plus one `f32` scale.  The scale is the
//! symmetric absmax step `max|x| / 127`, chosen either per segment
//! (*per-panel* mode: each append re-measures its own rows) or frozen
//! at the first segment (*per-head* mode: later appends reuse the
//! frozen scale and saturate at ±127 if they outgrow it).
//! Dequantization is `code as f32 * scale`.  An all-zero input has
//! `absmax == 0`; its scale is pinned to `0.0` so the round trip is
//! exactly zero (never `0/0 = NaN`).
//!
//! ## Why this file is tolerance-gated
//!
//! The quantize→dequantize round trip is lossy (per-element error is
//! at most `scale / 2`), so code built on these panels cannot promise
//! the repo's bit-identity contract.  It is the first sanctioned
//! departure: outputs computed from dequantized panels are gated by
//! the numeric tolerance declared in `oracle/policy.rs`
//! (`output_bits: {abs_tol, rel_tol}`) instead.  Everything here is
//! still deterministic (same input bytes → same codes) and panic-free
//! on the non-test paths, which is what the `tolerance-gated` lint
//! contract continues to enforce.
//!
//! ## Density math
//!
//! An f32 panel row of `D` columns is `4·D` bytes; the same row
//! quantized is `D` bytes plus an amortized 4-byte scale per segment —
//! ≥4× as many live rows (and therefore sessions) per byte of budget,
//! which is why the cache charges a quantized entry
//! `ceil(len / 4)` rows against the same LRU budget.

use std::sync::Arc;

use super::Matrix;

/// The symmetric i8 code range: codes live in `[-127, 127]` (−128 is
/// unused so the range is symmetric and negation is exact).
pub const QUANT_MAX: f32 = 127.0;

/// Symmetric absmax quantization step for one slice: `max|x| / 127`,
/// or `0.0` for an all-zero (or empty, or non-finite-free degenerate)
/// input so dequantization reproduces exact zeros instead of NaN.
pub fn symmetric_scale(xs: &[f32]) -> f32 {
    let absmax = xs.iter().fold(0.0f32, |a, &x| f32::max(a, x.abs()));
    if absmax > 0.0 && absmax.is_finite() {
        absmax / QUANT_MAX
    } else {
        0.0
    }
}

#[inline]
fn encode(x: f32, inv: f32) -> i8 {
    // NaN casts to 0, infinities clamp: the encoder never panics on
    // hostile floats, it degrades to the nearest representable code
    (x * inv).round().clamp(-QUANT_MAX, QUANT_MAX) as i8
}

/// One quantized panel segment: the i8 codes of one populate/append,
/// with the f32 scale they were encoded under.
#[derive(Debug)]
pub struct QuantSeg {
    rows: usize,
    cols: usize,
    scale: f32,
    codes: Vec<i8>,
}

impl QuantSeg {
    /// Quantize a matrix with its own symmetric absmax scale
    /// (per-panel mode).
    pub fn quantize(m: &Matrix) -> Self {
        Self::quantize_with(m, symmetric_scale(&m.data))
    }

    /// Quantize a matrix under a caller-pinned scale (per-head mode:
    /// the scale frozen at the first segment).  Values beyond
    /// `scale · 127` saturate.
    pub fn quantize_with(m: &Matrix, scale: f32) -> Self {
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        Self {
            rows: m.rows,
            cols: m.cols,
            scale,
            codes: m.data.iter().map(|&x| encode(x, inv)).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Append the dequantized f32 values (`code · scale`) to `out`.
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        out.extend(self.codes.iter().map(|&c| f32::from(c) * self.scale));
    }

    /// True stored bytes: one byte per element plus the f32 scale.
    pub fn quant_bytes(&self) -> usize {
        self.codes.len() + std::mem::size_of::<f32>()
    }
}

/// One head's quantized cached panel: the i8 sibling of the cache's
/// f32 `Panel` — immutable, Arc-shared, append-only segments (one per
/// populate/step), dequantized on solve into a plain [`Matrix`] so no
/// kernel changes its math.
#[derive(Debug, Clone)]
pub struct QuantPanel {
    rows: usize,
    cols: usize,
    segs: Vec<Arc<QuantSeg>>,
    /// Per-head mode: the scale frozen at the first segment (every
    /// later append reuses it).  `None` = per-panel mode (each segment
    /// carries its own absmax scale).
    frozen: Option<f32>,
}

impl QuantPanel {
    /// Seed a quantized panel from a freshly recomputed history.
    /// `per_head` freezes this first segment's scale for every later
    /// append; otherwise each append re-measures its own scale.
    pub fn from_matrix(m: &Matrix, per_head: bool) -> Self {
        let seg = QuantSeg::quantize(m);
        let frozen = if per_head { Some(seg.scale) } else { None };
        Self {
            rows: m.rows,
            cols: m.cols,
            segs: vec![Arc::new(seg)],
            frozen,
        }
    }

    /// Append a step's new rows as one fresh quantized segment (the
    /// history segments stay shared and untouched).
    pub fn append(&mut self, m: &Matrix) {
        debug_assert_eq!(m.cols, self.cols, "quant panel column mismatch");
        let seg = match self.frozen {
            Some(s) => QuantSeg::quantize_with(m, s),
            None => QuantSeg::quantize(m),
        };
        self.rows += m.rows;
        self.segs.push(Arc::new(seg));
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Dequantize the whole panel into a contiguous f32 matrix — the
    /// "reusable scratch" a hit's solve runs over.  Called outside the
    /// store lock; the Arcs keep every segment alive for as long as
    /// any snapshot does, exactly like the f32 panel path.
    pub fn to_matrix(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for seg in &self.segs {
            seg.dequantize_into(&mut data);
        }
        debug_assert_eq!(data.len(), self.rows * self.cols);
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// True stored bytes across all segments.
    pub fn quant_bytes(&self) -> usize {
        self.segs.iter().map(|s| s.quant_bytes()).fold(0, |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256;

    #[test]
    fn round_trip_error_is_bounded_by_half_a_step() {
        let mut rng = Xoshiro256::new(0xDEC1);
        let m = Matrix::randn(13, 7, &mut rng);
        let p = QuantPanel::from_matrix(&m, false);
        let back = p.to_matrix();
        assert_eq!((back.rows, back.cols), (13, 7));
        let scale = symmetric_scale(&m.data);
        assert!(scale > 0.0);
        let bound = scale * 0.5 + scale * 1e-3;
        for (a, b) in m.data.iter().zip(&back.data) {
            assert!((a - b).abs() <= bound,
                    "{a} vs {b} beyond half-step {bound}");
        }
    }

    #[test]
    fn all_zero_panel_round_trips_exactly() {
        // absmax == 0 pins the scale to 0.0: no NaN, exact zeros back
        let m = Matrix::zeros(5, 4);
        assert_eq!(symmetric_scale(&m.data), 0.0);
        for per_head in [false, true] {
            let mut p = QuantPanel::from_matrix(&m, per_head);
            p.append(&Matrix::zeros(2, 4));
            let back = p.to_matrix();
            assert!(back.bit_identical(&Matrix::zeros(7, 4)),
                    "per_head={per_head}");
        }
    }

    #[test]
    fn per_head_mode_freezes_the_first_scale_and_saturates() {
        let m0 = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let mut p = QuantPanel::from_matrix(&m0, true);
        // the frozen step is 1/127; rows appended later that outgrow
        // it clamp at ±127 · (1/127) = ±1
        p.append(&Matrix::from_vec(1, 2, vec![50.0, -50.0]));
        let back = p.to_matrix();
        assert_eq!(back.data, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn per_panel_mode_rescales_every_append() {
        let m0 = Matrix::from_vec(1, 2, vec![1.0, -1.0]);
        let mut p = QuantPanel::from_matrix(&m0, false);
        p.append(&Matrix::from_vec(1, 2, vec![50.0, -50.0]));
        let back = p.to_matrix();
        // each segment used its own absmax: large rows survive
        assert_eq!(back.data, vec![1.0, -1.0, 50.0, -50.0]);
    }

    #[test]
    fn quantization_is_deterministic() {
        let mut rng = Xoshiro256::new(0xDEC2);
        let m = Matrix::randn(9, 5, &mut rng);
        let a = QuantPanel::from_matrix(&m, false).to_matrix();
        let b = QuantPanel::from_matrix(&m, false).to_matrix();
        assert!(a.bit_identical(&b));
    }

    #[test]
    fn stored_bytes_are_one_per_element_plus_scales() {
        let mut rng = Xoshiro256::new(0xDEC3);
        let m = Matrix::randn(8, 6, &mut rng);
        let mut p = QuantPanel::from_matrix(&m, false);
        p.append(&Matrix::randn(2, 6, &mut rng));
        // 10 rows × 6 cols bytes + two 4-byte segment scales
        assert_eq!(p.quant_bytes(), 60 + 8);
        // ~4× denser than the f32 panel (240 bytes of rows)
        assert!(4 * p.quant_bytes() < 2 * 10 * 6 * 4);
    }

    #[test]
    fn hostile_floats_degrade_instead_of_panicking() {
        let m = Matrix::from_vec(1, 3,
                                 vec![f32::NAN, f32::INFINITY, 1.0]);
        // non-finite absmax pins the scale to 0.0: all codes decode to
        // exact zero rather than poisoning the panel with NaN
        let back = QuantPanel::from_matrix(&m, false).to_matrix();
        assert!(back.data.iter().all(|x| x.is_finite()));
    }
}
