//! ct-contract: bit-exact
//!
//! Batched (B, H, N, D) tensor layer for the multi-head attention engine.
//!
//! A [`BatchMatrix`] stacks `B·H` row-major `(N × D)` slices contiguously
//! — slice `s = b·H + h` holds head `h` of sequence `b`.  Kernels take
//! owned per-slice [`Matrix`] copies today ([`BatchMatrix::slice_matrix`];
//! the single-slice kernel API predates the batch layer), while outputs
//! are written zero-copy into disjoint chunks from
//! [`BatchMatrix::slices_mut`].  [`MatrixView`] is the read-side seam for
//! a future kernel API that borrows slices instead of copying them.
//!
//! **Ragged views.**  Serving pads variable-length sequences up to a
//! bucket length, so a slice often carries only `len < N` valid rows —
//! always the *leading* rows (`coordinator::pad_batch` zero-fills the
//! tail).  [`BatchMatrix::view_valid`] / [`BatchMatrix::slice_valid`]
//! expose exactly that prefix; because rows are contiguous, the valid
//! prefix of a padded slice is bit-for-bit the unpadded sequence, which
//! is what makes length-masked kernel runs exactly equal to unpadded
//! runs (see `attention::AttnProblem`).
//!
//! The flat layout is what the exec pool parallelizes over: slices are
//! independent, so (batch × head) is an embarrassingly parallel axis, and
//! the per-slice PRNG stream contract (`prng::slice_stream`) keeps the
//! parallel schedule bit-identical to the sequential one.

use crate::prng::Xoshiro256;
use crate::tensor::Matrix;

/// Dense (B, H, N, D) tensor, stored as B·H stacked row-major matrices.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchMatrix {
    /// Batch size B.
    pub batch: usize,
    /// Heads per sequence H.
    pub heads: usize,
    /// Rows per slice N (sequence length).
    pub rows: usize,
    /// Columns per slice D (head dimension).
    pub cols: usize,
    /// Contiguous storage, `batch * heads * rows * cols` elements.
    pub data: Vec<f32>,
}

impl BatchMatrix {
    pub fn zeros(batch: usize, heads: usize, rows: usize, cols: usize)
                 -> Self {
        Self {
            batch,
            heads,
            rows,
            cols,
            data: vec![0.0; batch * heads * rows * cols],
        }
    }

    pub fn randn(batch: usize, heads: usize, rows: usize, cols: usize,
                 rng: &mut Xoshiro256) -> Self {
        Self {
            batch,
            heads,
            rows,
            cols,
            data: rng.normal_vec(batch * heads * rows * cols),
        }
    }

    pub fn from_vec(batch: usize, heads: usize, rows: usize, cols: usize,
                    data: Vec<f32>) -> Self {
        assert_eq!(data.len(), batch * heads * rows * cols,
                   "shape mismatch");
        Self { batch, heads, rows, cols, data }
    }

    /// Stack owned per-slice matrices (all must share one shape).
    pub fn from_slices(batch: usize, heads: usize, slices: Vec<Matrix>)
                       -> Self {
        assert_eq!(slices.len(), batch * heads, "slice count mismatch");
        let Some(first) = slices.first() else {
            return Self { batch, heads, rows: 0, cols: 0,
                          data: Vec::new() };
        };
        let (rows, cols) = (first.rows, first.cols);
        let mut data = Vec::with_capacity(batch * heads * rows * cols);
        for m in &slices {
            assert_eq!((m.rows, m.cols), (rows, cols),
                       "ragged slices in BatchMatrix::from_slices");
            data.extend_from_slice(&m.data);
        }
        Self { batch, heads, rows, cols, data }
    }

    /// Number of independent (batch × head) slices.
    #[inline]
    pub fn slices(&self) -> usize {
        self.batch * self.heads
    }

    /// Elements per slice.
    #[inline]
    pub fn slice_len(&self) -> usize {
        self.rows * self.cols
    }

    /// Flat slice index for (sequence `b`, head `h`).
    #[inline]
    pub fn slice_index(&self, b: usize, h: usize) -> usize {
        debug_assert!(b < self.batch && h < self.heads);
        b * self.heads + h
    }

    /// Zero-copy read view of slice `s`.
    #[inline]
    pub fn view(&self, s: usize) -> MatrixView<'_> {
        let len = self.slice_len();
        MatrixView {
            rows: self.rows,
            cols: self.cols,
            data: &self.data[s * len..(s + 1) * len],
        }
    }

    /// Owned copy of slice `s` (for kernels that need a `Matrix`).
    pub fn slice_matrix(&self, s: usize) -> Matrix {
        let len = self.slice_len();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data[s * len..(s + 1) * len].to_vec(),
        }
    }

    /// Zero-copy view of the first `valid` rows of slice `s` — the
    /// ragged-serving view: rows are contiguous, so the valid prefix of
    /// a bucket-padded slice *is* the unpadded sequence.
    #[inline]
    pub fn view_valid(&self, s: usize, valid: usize) -> MatrixView<'_> {
        assert!(valid <= self.rows,
                "valid len {valid} exceeds slice rows {}", self.rows);
        let off = s * self.slice_len();
        MatrixView {
            rows: valid,
            cols: self.cols,
            data: &self.data[off..off + valid * self.cols],
        }
    }

    /// Owned copy of the first `valid` rows of slice `s` — the ragged
    /// sibling of [`BatchMatrix::slice_matrix`], which copies only the
    /// valid rows (`attention::AttentionKernel::solve_batch` resolves
    /// per-sequence lengths through this, so padded rows are never even
    /// copied, let alone computed).
    pub fn slice_valid(&self, s: usize, valid: usize) -> Matrix {
        self.view_valid(s, valid).to_matrix()
    }

    /// Mutable flat storage of slice `s`.
    #[inline]
    pub fn slice_mut(&mut self, s: usize) -> &mut [f32] {
        let len = self.slice_len();
        &mut self.data[s * len..(s + 1) * len]
    }

    /// Overwrite slice `s` from a same-shape matrix.
    pub fn set_slice(&mut self, s: usize, m: &Matrix) {
        assert_eq!((m.rows, m.cols), (self.rows, self.cols),
                   "set_slice shape mismatch");
        self.slice_mut(s).copy_from_slice(&m.data);
    }

    /// Split the storage into per-slice mutable chunks, slice order.
    /// This is how parallel writers get disjoint `&mut` access.
    pub fn slices_mut(&mut self) -> Vec<&mut [f32]> {
        let len = self.slice_len();
        if len == 0 {
            return Vec::new();
        }
        self.data.chunks_mut(len).collect()
    }

    pub fn max_abs_diff(&self, other: &BatchMatrix) -> f32 {
        assert_eq!(
            (self.batch, self.heads, self.rows, self.cols),
            (other.batch, other.heads, other.rows, other.cols)
        );
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Exact bitwise equality (the determinism contract's check).
    pub fn bit_identical(&self, other: &BatchMatrix) -> bool {
        self.batch == other.batch
            && self.heads == other.heads
            && self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

/// Borrowed row-major (N × D) view into one slice of a [`BatchMatrix`].
#[derive(Debug, Clone, Copy)]
pub struct MatrixView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatrixView<'a> {
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Owned copy.
    pub fn to_matrix(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_b_major_then_h() {
        let mut bm = BatchMatrix::zeros(2, 3, 4, 5);
        assert_eq!(bm.slices(), 6);
        assert_eq!(bm.slice_len(), 20);
        assert_eq!(bm.slice_index(1, 2), 5);
        bm.slice_mut(5)[0] = 9.0;
        assert_eq!(bm.data[5 * 20], 9.0);
        assert_eq!(bm.view(5).at(0, 0), 9.0);
    }

    #[test]
    fn from_slices_roundtrips_through_slice_matrix() {
        let mut rng = Xoshiro256::new(1);
        let ms: Vec<Matrix> =
            (0..6).map(|_| Matrix::randn(3, 4, &mut rng)).collect();
        let bm = BatchMatrix::from_slices(2, 3, ms.clone());
        for (s, m) in ms.iter().enumerate() {
            assert_eq!(&bm.slice_matrix(s), m);
            assert_eq!(bm.view(s).to_matrix(), *m);
        }
    }

    #[test]
    fn slices_mut_are_disjoint_and_cover() {
        let mut bm = BatchMatrix::zeros(2, 2, 2, 2);
        {
            let chunks = bm.slices_mut();
            assert_eq!(chunks.len(), 4);
            for (i, c) in chunks.into_iter().enumerate() {
                c.fill(i as f32);
            }
        }
        for s in 0..4 {
            assert!(bm.view(s).data.iter().all(|&x| x == s as f32));
        }
    }

    #[test]
    fn view_rows_match_matrix_rows() {
        let mut rng = Xoshiro256::new(2);
        let bm = BatchMatrix::randn(1, 2, 5, 3, &mut rng);
        let m = bm.slice_matrix(1);
        for r in 0..5 {
            assert_eq!(bm.view(1).row(r), m.row(r));
        }
    }

    #[test]
    fn valid_views_are_the_leading_rows_of_a_slice() {
        let mut rng = Xoshiro256::new(5);
        let bm = BatchMatrix::randn(2, 2, 6, 3, &mut rng);
        for s in 0..bm.slices() {
            let full = bm.slice_matrix(s);
            for valid in [0, 1, 4, 6] {
                let m = bm.slice_valid(s, valid);
                assert_eq!((m.rows, m.cols), (valid, 3));
                assert_eq!(m.data, full.data[..valid * 3], "slice {s}");
                assert_eq!(bm.view_valid(s, valid).to_matrix(), m);
            }
            // full-length valid view is exactly slice_matrix
            assert!(bm.slice_valid(s, 6).bit_identical(&full));
        }
    }

    #[test]
    #[should_panic(expected = "valid len")]
    fn valid_view_past_the_slice_panics() {
        BatchMatrix::zeros(1, 1, 4, 2).view_valid(0, 5);
    }

    #[test]
    fn bit_identical_detects_any_difference() {
        let mut rng = Xoshiro256::new(3);
        let a = BatchMatrix::randn(1, 1, 2, 2, &mut rng);
        let mut b = a.clone();
        assert!(a.bit_identical(&b));
        b.data[3] += 1e-7;
        assert!(!a.bit_identical(&b));
    }
}
