//! ct-contract: bit-exact
//! ct-lint: allow(det-float-accum, reason = "this file defines the pinned elementary accumulation order the det-float-accum rule protects everywhere else")
//!
//! Row-major f32 matrix substrate for the Rust reference attention and the
//! benchmark harness.  Deliberately minimal: contiguous `Vec<f32>`, blocked
//! matmul, row softmax, top-k, argsort — everything `attention/` needs.
//! The [`batch`] submodule adds the (B, H, N, D) stacked layout the
//! batched multi-head engine runs over; [`gemm`] is the cache-blocked,
//! panel-packed compute core `matmul`/`matmul_nt` delegate to;
//! [`quant`] is the symmetric-i8 panel storage behind the quantized
//! (tolerance-gated) KV-cache mode.

use crate::prng::Xoshiro256;

pub mod batch;
pub mod gemm;
pub mod quant;

pub use batch::{BatchMatrix, MatrixView};

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        Self { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// `self (m×k) @ other (k×n)` — the cache-blocked, panel-packed
    /// [`gemm`] core (sequential here; kernels thread an `ExecCtx`
    /// through [`gemm::matmul_nn`] for row-partitioned parallelism).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        gemm::matmul_nn(self, other, &crate::exec::ExecCtx::sequential())
    }

    /// `self @ other^T` — the attention-logits shape, blocked via
    /// [`gemm::matmul_nt`]; never materialises the transpose.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        gemm::matmul_nt(self, other, &crate::exec::ExecCtx::sequential())
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// In-place numerically-stable softmax over every row.
    pub fn softmax_rows(&mut self) {
        for r in 0..self.rows {
            softmax_inplace(self.row_mut(r));
        }
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Owned copy of the first `rows` rows — the "valid prefix" of a
    /// bucket-padded sequence (rows are contiguous in row-major storage,
    /// so the prefix of a padded matrix *is* the unpadded matrix).  The
    /// ragged-serving substrate `attention::AttnProblem` masks through
    /// exactly this view.
    pub fn row_prefix(&self, rows: usize) -> Matrix {
        assert!(rows <= self.rows,
                "row_prefix of {rows} rows from a {}-row matrix", self.rows);
        Matrix {
            rows,
            cols: self.cols,
            data: self.data[..rows * self.cols].to_vec(),
        }
    }

    /// Owned copy of rows `start..end` — the incremental-decode query
    /// span ([`row_prefix`] generalized to an interior range).
    ///
    /// [`row_prefix`]: Matrix::row_prefix
    pub fn row_span(&self, start: usize, end: usize) -> Matrix {
        assert!(start <= end && end <= self.rows,
                "row_span {start}..{end} from a {}-row matrix", self.rows);
        Matrix {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }

    /// Exact bitwise equality — the check behind the compute-core
    /// determinism contract (the single-slice sibling of
    /// [`BatchMatrix::bit_identical`]).
    pub fn bit_identical(&self, other: &Matrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled; autovectorises well in release builds.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `out += w * row`.
#[inline]
pub fn axpy(out: &mut [f32], w: f32, row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    for i in 0..out.len() {
        out[i] += w * row[i];
    }
}

/// Numerically stable in-place softmax of one slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        let u = 1.0 / xs.len() as f32;
        for v in xs.iter_mut() {
            *v = u;
        }
        return;
    }
    let mut sum = 0.0;
    for v in xs.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

/// Indices of the `k` largest values (descending), stable on ties.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    if k == 0 {
        // select_nth on an empty index set would panic; `topk == 0` (or
        // an empty input) legitimately selects nothing
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
    idx
}

/// Stable argsort ascending.
pub fn argsort<T: PartialOrd>(xs: &[T]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap().then(a.cmp(&b)));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_nt_matches_matmul_transpose() {
        let mut rng = Xoshiro256::new(1);
        let a = Matrix::randn(5, 7, &mut rng);
        let b = Matrix::randn(6, 7, &mut rng);
        let c1 = a.matmul_nt(&b);
        let c2 = a.matmul(&b.transpose());
        assert!(c1.max_abs_diff(&c2) < 1e-5);
    }

    #[test]
    fn softmax_rows_are_distributions() {
        let mut rng = Xoshiro256::new(2);
        let mut m = Matrix::randn(4, 9, &mut rng);
        m.softmax_rows();
        for r in 0..4 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_all_neg_inf() {
        let mut xs = vec![f32::NEG_INFINITY; 4];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topk_descending_and_correct() {
        let xs = vec![0.5, 3.0, -1.0, 3.0, 2.0];
        assert_eq!(topk_indices(&xs, 3), vec![1, 3, 4]);
        assert_eq!(topk_indices(&xs, 10).len(), 5);
    }

    #[test]
    fn topk_with_k_at_least_n_is_a_full_stable_sort() {
        let xs = vec![1.0, 4.0, 4.0, -2.0, 0.0];
        // k == n and k > n both return every index, descending, ties
        // broken by position
        assert_eq!(topk_indices(&xs, 5), vec![1, 2, 4, 0, 3]);
        assert_eq!(topk_indices(&xs, 100), vec![1, 2, 4, 0, 3]);
    }

    #[test]
    fn topk_zero_and_empty_inputs_select_nothing() {
        assert_eq!(topk_indices(&[1.0, 2.0], 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&[], 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&[], 3), Vec::<usize>::new());
    }

    #[test]
    fn topk_tied_scores_keep_position_order() {
        let xs = vec![7.0; 6];
        assert_eq!(topk_indices(&xs, 4), vec![0, 1, 2, 3]);
        // ties spanning the selection boundary stay stable too
        let xs = vec![1.0, 5.0, 5.0, 5.0, 0.0];
        assert_eq!(topk_indices(&xs, 2), vec![1, 2]);
    }

    #[test]
    fn row_prefix_is_the_leading_rows_verbatim() {
        let mut rng = Xoshiro256::new(9);
        let m = Matrix::randn(6, 3, &mut rng);
        let p = m.row_prefix(4);
        assert_eq!((p.rows, p.cols), (4, 3));
        assert_eq!(p.data, m.data[..12]);
        // degenerate prefixes: everything and nothing
        assert!(m.row_prefix(6).bit_identical(&m));
        assert_eq!(m.row_prefix(0).data, Vec::<f32>::new());
    }

    #[test]
    #[should_panic(expected = "row_prefix")]
    fn row_prefix_past_the_end_panics() {
        Matrix::zeros(2, 2).row_prefix(3);
    }

    #[test]
    fn argsort_stable() {
        let xs = vec![2.0, 1.0, 2.0, 0.0];
        assert_eq!(argsort(&xs), vec![3, 1, 0, 2]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Xoshiro256::new(3);
        let a = Matrix::randn(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), naive);
    }
}
