//! `doc-family-drift`: the kernel registry vs. the documentation.
//!
//! `attention/mod.rs` is the single source of truth for which kernel
//! families exist (`REGISTRY`, keyed by paper-notation name).  The
//! README quickstart and `docs/ARCHITECTURE.md` both carry family
//! lists a newcomer reads first — and nothing kept them honest when a
//! family landed (PRs 4/5/8 each added one).  This rule extracts
//! every `key: "…"` from the registry and requires the key string to
//! appear in both documents.

use super::rules::Hit;

/// Extract `(key, line)` pairs from `key: "…"` bindings in the
/// registry source.
pub fn registry_keys(mod_src: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (i, line) in mod_src.split('\n').enumerate() {
        let Some(p) = line.find("key:") else { continue };
        let rest = line[p + 4..].trim_start();
        let Some(rest) = rest.strip_prefix('"') else { continue };
        let Some(end) = rest.find('"') else { continue };
        let key = &rest[..end];
        if !key.is_empty() {
            out.push((key.to_string(), i + 1));
        }
    }
    out
}

/// Check every registry key against the named documents.  `docs` is
/// `(display-name, contents)`; a key missing from any document is one
/// violation anchored at its registry line.
pub fn family_drift(mod_src: &str, docs: &[(&str, &str)]) -> Vec<Hit> {
    let mut hits = Vec::new();
    for (key, line) in registry_keys(mod_src) {
        let missing: Vec<&str> = docs
            .iter()
            .filter(|(_, text)| !text.contains(key.as_str()))
            .map(|(name, _)| *name)
            .collect();
        if !missing.is_empty() {
            hits.push(Hit {
                rule: "doc-family-drift",
                line,
                msg: format!("kernel family `{key}` missing from {}",
                             missing.join(", ")),
            });
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;

    const REG: &str = "\
        KernelFamily { key: \"full\", parse: parse_full },\n\
        KernelFamily { key: \"lsh\", parse: parse_lsh },\n";

    #[test]
    fn extracts_keys_with_lines() {
        assert_eq!(registry_keys(REG),
                   vec![("full".to_string(), 1),
                        ("lsh".to_string(), 2)]);
    }

    #[test]
    fn missing_key_is_flagged_per_document() {
        let hits = family_drift(
            REG,
            &[("README.md", "full attention and lsh hashing"),
              ("docs/ARCHITECTURE.md", "only full here")]);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 2);
        assert!(hits[0].msg.contains("lsh"));
        assert!(hits[0].msg.contains("ARCHITECTURE"));
        assert!(!hits[0].msg.contains("README"));
    }

    #[test]
    fn present_everywhere_is_clean() {
        let hits = family_drift(
            REG, &[("README.md", "full, lsh"), ("A.md", "lsh full")]);
        assert!(hits.is_empty());
    }
}
