//! The rule catalog: every per-line contract check `ct lint` ships.
//!
//! Each rule has a machine-readable id (stable — suppressions and CI
//! greps key on it), a scope (which files it applies to, decided by
//! `lint::mod`), and a matcher over one scanned line.  Matchers run on
//! the *code view* of a line (strings/comments blanked, positions
//! preserved — see `scan`), so a pattern inside a string literal or a
//! comment can never fire.
//!
//! The determinism family encodes the repo's "partition rows, never
//! split reductions" bit-contract; the panic family encodes the
//! PR 6/7 graceful-degradation promise on the serving surface; the
//! wire/doc families make the byte-stable protocol and the kernel
//! registry's documentation reviewable diffs instead of tribal
//! knowledge.  The full catalog with rationale and suppression
//! etiquette lives in `docs/TESTING.md`.

use super::scan::FileScan;

/// Every rule id the engine knows.  `allow(...)` directives naming
/// anything else raise `lint-unknown-rule`.
pub const RULE_IDS: &[&str] = &[
    "det-float-reduce",
    "det-float-accum",
    "det-map-iter",
    "det-entropy",
    "det-seed-arith",
    "panic-unwrap",
    "panic-expect",
    "panic-macro",
    "panic-index",
    "wire-field",
    "doc-family-drift",
    "contract-header",
    "lint-no-reason",
    "lint-unknown-rule",
];

/// Is `rule` a known rule id?
pub fn known_rule(rule: &str) -> bool {
    RULE_IDS.contains(&rule)
}

/// Every contract a `//! ct-contract:` header may declare.
///
/// - `bit-exact` — outputs are a bit-deterministic function of the
///   inputs AND bit-identical to the reference schedule; the
///   `det-float-*` / `det-map-iter` rules enforce it.
/// - `panic-free` — the file is on a serving path and must degrade
///   instead of crash; the `panic-*` rules enforce it.
/// - `tolerance-gated` — quantized/reduced-precision code: exempt
///   from the bit-identity rules (its outputs are gated by the
///   numeric tolerance in `oracle/tolerance-policy.json` instead),
///   but still deterministic in structure and held to the full
///   `panic-*` family — lossy storage must never become lossy
///   control flow.
pub const CONTRACTS: &[&str] = &["bit-exact", "panic-free",
                                 "tolerance-gated"];

/// Is `name` a contract the engine knows?  Headers naming anything
/// else raise `contract-header` — a typoed contract must fail loudly,
/// not silently exempt a file.
pub fn known_contract(name: &str) -> bool {
    CONTRACTS.contains(&name)
}

/// A raw rule hit before suppression resolution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hit {
    /// Rule id (one of [`RULE_IDS`]).
    pub rule: &'static str,
    /// 1-based line.
    pub line: usize,
    /// Human-readable description of the specific hit.
    pub msg: String,
}

fn hit(rule: &'static str, line: usize, msg: impl Into<String>) -> Hit {
    Hit { rule, line, msg: msg.into() }
}

/// `det-float-reduce`: `.sum()` / `.product()` / `.fold(` /
/// `.reduce(` in a bit-exact file.  Iterator reductions hide their
/// association order behind the adapter chain; the bit-contract
/// requires the order to be visible and pinned.  Exemption: folds
/// whose combiner is `f32::max` / `f32::min` (order-insensitive over
/// the finite inputs these kernels produce) — detected by looking at
/// the text following `.fold(` on this and the next raw line.
pub fn det_float_reduce(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    for pat in [".sum()", ".sum::", ".product()"] {
        if let Some(p) = lt.find(pat) {
            hits.push(hit("det-float-reduce", i + 1,
                          format!("iterator reduction `{}` hides its \
                                   association order",
                                  &lt[p + 1..p + pat.len()])));
        }
    }
    for pat in [".fold(", ".reduce("] {
        let Some(p) = lt.find(pat) else { continue };
        // look ahead on the raw view for a max/min combiner
        let mut look = fs.raw_lines[i][(p + pat.len()).min(
            fs.raw_lines[i].len())..].to_string();
        if let Some(next) = fs.raw_lines.get(i + 1) {
            look.push(' ');
            look.push_str(next);
        }
        let look: String = look.chars().take(120).collect();
        if look.contains("f32::max") || look.contains("f32::min") {
            continue;
        }
        hits.push(hit("det-float-reduce", i + 1,
                      format!("`{}` reduction without a pinned order",
                              pat.trim_end_matches('('))));
    }
    hits
}

/// `det-float-accum`: compound `+=` accumulation inside a loop body
/// in a bit-exact file — the shape of a float reduction written by
/// hand.  Plain counter bumps are exempt: a hit needs an indexed
/// left-hand side or a right-hand side with a product, call or index
/// (`s0 += n` passes, `acc[c] += a * b` does not), and `+= 1` never
/// fires.  Files that *are* the pinned elementary order (`tensor/`
/// dot/axpy, the GEMM microkernel) carry file-scope allows saying so.
pub fn det_float_accum(fs: &FileScan, i: usize) -> Vec<Hit> {
    if !fs.in_loop[i] {
        return Vec::new();
    }
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    let mut from = 0usize;
    while let Some(p) = lt[from..].find("+=") {
        let p = from + p;
        from = p + 2;
        let left = &lt[..p];
        let left = left
            .rfind([';', '{', '('])
            .map_or(left, |c| &left[c + 1..]);
        let right = &lt[p + 2..];
        let right = right.split(';').next().unwrap_or(right);
        let rt = right.trim();
        if rt == "1" || rt == "1.0" {
            continue;
        }
        if left.contains('[')
            || right.contains('*')
            || right.contains('(')
            || right.contains('[')
        {
            hits.push(hit("det-float-accum", i + 1,
                          "compound `+=` accumulation in a loop body"));
        }
    }
    hits
}

/// `det-map-iter`: `HashMap` / `HashSet` in a bit-exact file.  Their
/// iteration order is randomized per process; anything order-dependent
/// (eviction tie-breaks, output assembly) must use `BTreeMap` or sort
/// explicitly.  Presence (not just iteration) is flagged: keyed-only
/// use is one refactor away from an ordered walk, and `BTreeMap` costs
/// nothing at these sizes.
pub fn det_map_iter(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    for pat in ["HashMap", "HashSet"] {
        if find_word(lt, pat).is_some() {
            hits.push(hit("det-map-iter", i + 1,
                          format!("`{pat}` in a bit-exact file \
                                   (iteration order is randomized; \
                                   use BTreeMap/BTreeSet or sort)")));
        }
    }
    hits
}

/// `det-entropy`: ambient entropy or clock sources outside `prng/`
/// and `benchlib/`.  Wall-clock reads are fine for latency metrics —
/// files doing only that carry a file-scope allow saying so — but a
/// clock or RNG feeding the math breaks replay.
pub fn det_entropy(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    for pat in ["thread_rng", "rand::", "Instant::now",
                "SystemTime::now", "from_entropy"] {
        if lt.contains(pat) {
            hits.push(hit("det-entropy", i + 1,
                          format!("ambient entropy/clock source `{pat}`")));
        }
    }
    hits
}

/// `det-seed-arith`: raw arithmetic on a value named `seed` (xor,
/// `wrapping_*`) outside `prng/` and `benchlib/`.  Ad-hoc seed
/// splitting collides streams; `prng::slice_stream` /
/// `prng::session_seed` are the sanctioned derivations.
pub fn det_seed_arith(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    let found = seed_xor(lt)
        || lt.contains("seed.wrapping_add")
        || lt.contains("seed.wrapping_mul")
        || lt.contains("seed.wrapping_sub")
        || lt.contains("seed.wrapping_shl");
    if found {
        hits.push(hit("det-seed-arith", i + 1,
                      "raw seed arithmetic (use prng::slice_stream / \
                       prng::session_seed)"));
    }
    hits
}

/// Whole-word `seed` adjacent to a `^` operator.
fn seed_xor(lt: &str) -> bool {
    let mut from = 0usize;
    while let Some(p) = find_word(&lt[from..], "seed") {
        let p = from + p;
        let after = lt[p + 4..].trim_start();
        if after.starts_with('^') && !after.starts_with("^=") {
            return true;
        }
        let before = lt[..p].trim_end();
        if before.ends_with('^') {
            return true;
        }
        from = p + 4;
    }
    false
}

/// `panic-unwrap`: `.unwrap()` on the serving surface.  These paths
/// promised graceful degradation (PR 6/7): errors come back on the
/// wire or fall back to local compute, they never kill a dispatcher
/// thread.  `exec::lock_unpoisoned` is the sanctioned replacement for
/// mutex guards.
pub fn panic_unwrap(fs: &FileScan, i: usize) -> Vec<Hit> {
    if fs.code_lines[i].contains(".unwrap()") {
        vec![hit("panic-unwrap", i + 1,
                 "`.unwrap()` on the serving surface")]
    } else {
        Vec::new()
    }
}

/// `panic-expect`: `.expect(` on the serving surface (same contract
/// as `panic-unwrap`).
pub fn panic_expect(fs: &FileScan, i: usize) -> Vec<Hit> {
    if fs.code_lines[i].contains(".expect(") {
        vec![hit("panic-expect", i + 1,
                 "`.expect(…)` on the serving surface")]
    } else {
        Vec::new()
    }
}

/// `panic-macro`: `panic!` / `unreachable!` / `todo!` /
/// `unimplemented!` on the serving surface.
pub fn panic_macro(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = &fs.code_lines[i];
    let mut hits = Vec::new();
    for pat in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        if find_word(lt, pat.trim_end_matches('!'))
            .map(|p| lt[p..].starts_with(pat))
            .unwrap_or(false)
        {
            hits.push(hit("panic-macro", i + 1,
                          format!("`{pat}` on the serving surface")));
        }
    }
    hits
}

/// `panic-index`: unguarded slice/array indexing on the serving
/// surface.  Ranges (`a[s..e]`) and pure integer literals (`c[0]`)
/// are exempt — the former are the panel-view idiom whose bounds the
/// shape checks established, the latter are fixed-arity destructuring.
/// Everything else should be `get()`-guarded or carry an allow whose
/// reason names the invariant making the index safe.
pub fn panic_index(fs: &FileScan, i: usize) -> Vec<Hit> {
    let lt = fs.code_lines[i].as_bytes();
    let mut hits = Vec::new();
    let mut j = 1usize;
    while j < lt.len() {
        if lt[j] != b'[' {
            j += 1;
            continue;
        }
        let prev = lt[j - 1];
        let indexes = prev.is_ascii_alphanumeric()
            || prev == b'_'
            || prev == b')'
            || prev == b']';
        if !indexes {
            j += 1;
            continue;
        }
        // matching close bracket
        let mut depth = 1usize;
        let mut k = j + 1;
        while k < lt.len() && depth > 0 {
            match lt[k] {
                b'[' => depth += 1,
                b']' => depth -= 1,
                _ => {}
            }
            k += 1;
        }
        let inner =
            &fs.code_lines[i][j + 1..(k - 1).max(j + 1).min(lt.len())];
        let trimmed = inner.trim();
        let literal = !trimmed.is_empty()
            && trimmed.chars().all(|c| c.is_ascii_digit() || c == '_');
        if !inner.contains("..") && !literal && !trimmed.is_empty() {
            hits.push(hit("panic-index", i + 1,
                          format!("unguarded index `[{trimmed}]`")));
        }
        j = k.max(j + 1);
    }
    hits
}

/// Find `word` at an identifier boundary; returns the byte offset.
fn find_word(hay: &str, word: &str) -> Option<usize> {
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(word) {
        let p = from + p;
        let before_ok = p == 0
            || !hay.as_bytes()[p - 1].is_ascii_alphanumeric()
                && hay.as_bytes()[p - 1] != b'_';
        let end = p + word.len();
        let after_ok = end >= hay.len()
            || !hay.as_bytes()[end].is_ascii_alphanumeric()
                && hay.as_bytes()[end] != b'_';
        if before_ok && after_ok {
            return Some(p);
        }
        from = p + word.len();
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> FileScan {
        FileScan::new("t.rs", src)
    }

    #[test]
    fn contract_catalog_matches_known_contract() {
        for c in CONTRACTS {
            assert!(known_contract(c));
        }
        assert!(known_contract("tolerance-gated"));
        assert!(!known_contract("bit-exactt"));
        assert!(!known_contract(""));
    }

    #[test]
    fn float_reduce_flags_sum_not_maxfold() {
        let fs = scan("fn f() {\n\
                       let a: f32 = xs.iter().sum();\n\
                       let m = xs.iter().fold(f32::NEG_INFINITY, f32::max);\n\
                       let t = xs.iter().fold(0.0, |a, b| a + b);\n\
                       }");
        assert_eq!(det_float_reduce(&fs, 1).len(), 1);
        assert!(det_float_reduce(&fs, 2).is_empty());
        assert_eq!(det_float_reduce(&fs, 3).len(), 1);
    }

    #[test]
    fn float_reduce_maxfold_combiner_on_next_line() {
        let fs = scan("let m = xs.iter().copied().fold(f32::NEG_INFINITY,\n\
                       f32::max);");
        assert!(det_float_reduce(&fs, 0).is_empty());
    }

    #[test]
    fn float_accum_skips_counters() {
        let src = "fn f() {\nfor x in xs {\n\
                   total += 1;\n\
                   off += n;\n\
                   acc[c] += a * b;\n\
                   s += a[i];\n\
                   }\n}";
        let fs = scan(src);
        assert!(det_float_accum(&fs, 2).is_empty());
        assert!(det_float_accum(&fs, 3).is_empty());
        assert_eq!(det_float_accum(&fs, 4).len(), 1);
        assert_eq!(det_float_accum(&fs, 5).len(), 1);
    }

    #[test]
    fn float_accum_outside_loop_is_fine() {
        let fs = scan("fn f() {\nacc[c] += a * b;\n}");
        assert!(det_float_accum(&fs, 1).is_empty());
    }

    #[test]
    fn map_iter_flags_hashmap() {
        let fs = scan("use std::collections::HashMap;\nlet m: BTreeMap<u8, u8>;");
        assert_eq!(det_map_iter(&fs, 0).len(), 1);
        assert!(det_map_iter(&fs, 1).is_empty());
    }

    #[test]
    fn entropy_and_seed_arith() {
        let fs = scan("let t = Instant::now();\n\
                       let s = seed ^ 0xDEC0;\n\
                       let u = prng::session_seed(seed, id);\n\
                       let w = reseed ^ 1;");
        assert_eq!(det_entropy(&fs, 0).len(), 1);
        assert_eq!(det_seed_arith(&fs, 1).len(), 1);
        assert!(det_seed_arith(&fs, 2).is_empty());
        assert!(det_seed_arith(&fs, 3).is_empty()); // not the word `seed`
    }

    #[test]
    fn panic_family() {
        let fs = scan("a.unwrap();\nb.expect(\"x\");\npanic!(\"y\");\n\
                       c.unwrap_or_default();");
        assert_eq!(panic_unwrap(&fs, 0).len(), 1);
        assert_eq!(panic_expect(&fs, 1).len(), 1);
        assert_eq!(panic_macro(&fs, 2).len(), 1);
        assert!(panic_unwrap(&fs, 3).is_empty());
    }

    #[test]
    fn index_rule_exemptions() {
        let fs = scan("let a = xs[i];\n\
                       let b = xs[s..e];\n\
                       let c = xs[0];\n\
                       let d = vec![0.0; n];\n\
                       #[cfg(feature = \"x\")] fn g() {}\n\
                       let e = m[k % m.len()];");
        assert_eq!(panic_index(&fs, 0).len(), 1);
        assert!(panic_index(&fs, 1).is_empty());
        assert!(panic_index(&fs, 2).is_empty());
        assert!(panic_index(&fs, 3).is_empty());
        assert!(panic_index(&fs, 4).is_empty());
        assert_eq!(panic_index(&fs, 5).len(), 1);
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let fs = scan("let s = \"a.unwrap() Instant::now HashMap\";\n\
                       // xs[i].unwrap() in a comment\n\
                       let t = 1;");
        assert!(panic_unwrap(&fs, 0).is_empty());
        assert!(det_entropy(&fs, 0).is_empty());
        assert!(det_map_iter(&fs, 0).is_empty());
        assert!(panic_index(&fs, 1).is_empty());
    }
}
