//! `ct lint` — the contract-aware static-analysis pass.
//!
//! Every subsystem in this crate rests on hand-maintained invariants:
//! the "partition rows, never split reductions" bit-determinism
//! contract, all randomness flowing through `prng`, panic-free
//! serving paths that degrade instead of crash, and byte-stable JSON
//! wire formats.  This module makes those contracts machine-checked
//! artifacts instead of tribal knowledge: a std-only, source-level
//! pass (lightweight lexical scanner, no syn/proc-macro) over the
//! crate's own sources, run as `ct lint` and gated release-blocking
//! in CI next to the golden-trace oracle.
//!
//! Layout:
//! - [`scan`] — position-preserving lexical scanner (strings/comments
//!   blanked, test/loop scope, suppression directives).
//! - [`rules`] — the per-line rule catalog (determinism, panic-safety
//!   families) with stable machine-readable ids.
//! - [`wire`] — the wire-field allowlist check over the JSON protocol
//!   surface (`lint/wire-fields.json`).
//! - [`docs`] — kernel-registry vs. README/ARCHITECTURE drift.
//! - [`report`] — the byte-stable `lint-report.json` artifact.
//!
//! Scopes are path-based and spelled out in [`bit_scope`],
//! [`panic_scope`], [`entropy_scope`] and [`wire_scope`]; a file can
//! additionally opt in to a contract with a `//! ct-contract:` header
//! (mandatory in the scoped directories — `contract-header` enforces
//! that, so deleting the header is itself a violation).  Suppressions
//! require a reason:
//!
//! ```text
//! // ct-lint: allow(panic-index, reason = "idx < lanes checked above")
//! ```
//!
//! The rule catalog with rationale and suppression etiquette lives in
//! `docs/TESTING.md`.

pub mod docs;
pub mod report;
pub mod rules;
pub mod scan;
pub mod wire;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use report::{LintReport, Suppression, Violation};
use rules::Hit;
use scan::FileScan;

/// Everything one lint pass consumes, decoupled from the filesystem
/// so tests and the self-check can feed synthetic trees.
pub struct SourceSet {
    /// `(path, contents)` with paths relative to `rust/src/`, forward
    /// slashes.  Order does not matter — the report sorts.
    pub files: Vec<(String, String)>,
    /// `(display-name, contents)` of the documents the doc-drift rule
    /// checks (README.md, docs/ARCHITECTURE.md).
    pub docs: Vec<(String, String)>,
    /// The wire-field allowlist (field names).
    pub wire_allow: Vec<String>,
}

/// Files under the bit-determinism contract: the kernel, tensor and
/// execution layers.  These must carry `//! ct-contract: bit-exact`
/// and pass the `det-float-*` / `det-map-iter` rules — or declare
/// `//! ct-contract: tolerance-gated` (sanctioned lossy code such as
/// `tensor/quant.rs`), which trades the bit-identity rules for the
/// numeric tolerance policy while keeping `det-map-iter` and the full
/// panic family.
pub fn bit_scope(path: &str) -> bool {
    path.starts_with("attention/")
        || path.starts_with("tensor/")
        || path.starts_with("exec/")
}

/// The serving surface that promised graceful degradation (PR 6/7):
/// wire server, coordinator (minus the offline training/data paths),
/// the sharded fan-out, and the oracle harness that replays against
/// them.  These must carry `//! ct-contract: panic-free` and pass the
/// `panic-*` rules.
pub fn panic_scope(path: &str) -> bool {
    if path.starts_with("server/") || path.starts_with("oracle/") {
        return true;
    }
    if path == "attention/sharded.rs" {
        return true;
    }
    // trainer/datafeed are the offline training loop — they may
    // assert on programmer error; everything else in coordinator/
    // is on a request path
    path.starts_with("coordinator/")
        && !path.ends_with("trainer.rs")
        && !path.ends_with("datafeed.rs")
}

/// Everywhere except the sanctioned randomness/timing homes.
pub fn entropy_scope(path: &str) -> bool {
    !path.starts_with("prng/") && !path.starts_with("benchlib/")
}

/// The JSON wire surface the `wire-field` allowlist covers: the
/// gateway JSON-lines protocol and the shard wire header.
pub fn wire_scope(path: &str) -> bool {
    path.starts_with("server/") || path == "attention/sharded.rs"
}

/// Run the full pass over a [`SourceSet`].  Pure: no filesystem, no
/// clock — the report is a deterministic function of the inputs.
pub fn analyze(set: &SourceSet) -> LintReport {
    let mut rep = LintReport {
        files_scanned: set.files.len(),
        ..LintReport::default()
    };
    for (path, text) in &set.files {
        let fs = FileScan::new(path, text);
        analyze_file(&fs, set, &mut rep);
    }
    // registry vs docs drift (anchored in attention/mod.rs)
    if let Some((path, text)) =
        set.files.iter().find(|(p, _)| p == "attention/mod.rs")
    {
        let doc_refs: Vec<(&str, &str)> = set
            .docs
            .iter()
            .map(|(n, t)| (n.as_str(), t.as_str()))
            .collect();
        let fs = FileScan::new(path, text);
        for h in docs::family_drift(text, &doc_refs) {
            file_hit(&fs, h, &mut rep);
        }
    }
    rep.sort();
    rep
}

/// All per-line rules plus directive hygiene for one scanned file.
fn analyze_file(fs: &FileScan, set: &SourceSet, rep: &mut LintReport) {
    // directive hygiene first: a reasonless or unknown allow is a
    // violation in its own right and never suppresses anything
    for a in &fs.allows {
        if !rules::known_rule(&a.rule) {
            rep.violations.push(Violation {
                file: report_path(&fs.path),
                line: a.line,
                rule: "lint-unknown-rule".to_string(),
                msg: format!("allow({}) names an unknown rule", a.rule),
            });
        } else if a.reason.is_empty() {
            rep.violations.push(Violation {
                file: report_path(&fs.path),
                line: a.line,
                rule: "lint-no-reason".to_string(),
                msg: format!("allow({}) must carry \
                              `reason = \"…\"`", a.rule),
            });
        }
    }

    // a header naming an unknown contract is a violation in its own
    // right — a typo must fail loudly, not silently exempt the file
    for c in &fs.contracts {
        if !rules::known_contract(c) {
            file_hit(fs, Hit {
                rule: "contract-header",
                line: 1,
                msg: format!("unknown contract {c:?} (known: {})",
                             rules::CONTRACTS.join(", ")),
            }, rep);
        }
    }

    // contract headers are mandatory inside the scoped directories.
    // bit scope accepts `tolerance-gated` in place of `bit-exact`:
    // quantized/reduced-precision files trade the bit-identity rules
    // for the numeric tolerance policy (and keep the panic family).
    let tol = fs.has_contract("tolerance-gated");
    if bit_scope(&fs.path) && !fs.has_contract("bit-exact") && !tol {
        file_hit(fs, Hit {
            rule: "contract-header",
            line: 1,
            msg: "missing `//! ct-contract: bit-exact` header (or \
                  `tolerance-gated` for sanctioned lossy code)"
                .to_string(),
        }, rep);
    }
    if panic_scope(&fs.path) && !fs.has_contract("panic-free") {
        file_hit(fs, Hit {
            rule: "contract-header",
            line: 1,
            msg: "missing `//! ct-contract: panic-free` header"
                .to_string(),
        }, rep);
    }

    let bit = fs.has_contract("bit-exact");
    // tolerance-gated implies panic-free: lossy storage must degrade,
    // never crash, so the panic family stays on
    let panics = panic_scope(&fs.path) || fs.has_contract("panic-free")
        || tol;
    let entropy = entropy_scope(&fs.path);
    let wire = wire_scope(&fs.path);

    for i in 0..fs.code_lines.len() {
        if fs.in_test[i] {
            continue;
        }
        let mut hits: Vec<Hit> = Vec::new();
        if bit {
            hits.extend(rules::det_float_reduce(fs, i));
            hits.extend(rules::det_float_accum(fs, i));
        }
        if bit || tol {
            // map-iteration order is a structural hazard, not a
            // rounding one — tolerance-gated files don't get it back
            hits.extend(rules::det_map_iter(fs, i));
        }
        if entropy {
            hits.extend(rules::det_entropy(fs, i));
            hits.extend(rules::det_seed_arith(fs, i));
        }
        if panics {
            hits.extend(rules::panic_unwrap(fs, i));
            hits.extend(rules::panic_expect(fs, i));
            hits.extend(rules::panic_macro(fs, i));
            hits.extend(rules::panic_index(fs, i));
        }
        if wire {
            hits.extend(wire::wire_field(fs, i, &set.wire_allow));
        }
        for h in hits {
            file_hit(fs, h, rep);
        }
    }
}

/// Route one hit through suppression resolution into the report.
fn file_hit(fs: &FileScan, h: Hit, rep: &mut LintReport) {
    match fs.suppression(h.rule, h.line) {
        Some(reason) => rep.suppressions.push(Suppression {
            file: report_path(&fs.path),
            line: h.line,
            rule: h.rule.to_string(),
            reason: reason.to_string(),
        }),
        None => rep.violations.push(Violation {
            file: report_path(&fs.path),
            line: h.line,
            rule: h.rule.to_string(),
            msg: h.msg,
        }),
    }
}

/// Report paths are repo-relative: `rust/src/` + the scan-relative
/// path (synthetic self-check probes keep their marker prefix).
fn report_path(path: &str) -> String {
    if path.starts_with("__lint_probe") || path.contains("__lint_probe") {
        path.to_string()
    } else {
        format!("rust/src/{path}")
    }
}

/// Collect the real tree under `<root>/rust/src` into a
/// [`SourceSet`], reading the wire allowlist embedded at compile time
/// and the drift documents from disk.
pub fn source_set(root: &Path) -> Result<SourceSet> {
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &src, &mut files)?;
    files.sort();
    let mut docs = Vec::new();
    for name in ["README.md", "docs/ARCHITECTURE.md"] {
        let p = root.join(name);
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        docs.push((name.to_string(), text));
    }
    let wire_allow = wire::parse_allowlist(wire::WIRE_FIELDS_JSON)
        .context("lint/wire-fields.json is malformed")?;
    Ok(SourceSet { files, docs, wire_allow })
}

/// Recursively gather `*.rs` under `dir`, paths relative to `base`.
fn collect_rs(base: &Path, dir: &Path,
              out: &mut Vec<(String, String)>) -> Result<()> {
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?;
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(base, &p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(base)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {}", p.display()))?;
            out.push((rel, text));
        }
    }
    Ok(())
}

/// Run the pass over the repo at `root`.
pub fn run(root: &Path) -> Result<LintReport> {
    Ok(analyze(&source_set(root)?))
}

/// Default report path: `<repo>/lint-report.json`, next to the oracle
/// and bench reports.
pub fn default_report_path() -> PathBuf {
    crate::config::find_repo_root().join("lint-report.json")
}

/// Outcome of [`self_check`].
pub struct SelfCheck {
    /// Rule ids that failed to fire on the injected probes — empty
    /// when the red path is healthy.
    pub missed: Vec<&'static str>,
    /// How many injected violations were detected.
    pub injected: usize,
    /// The combined report (real tree + probes).
    pub report: LintReport,
}

/// Prove the red path: inject synthetic probe files carrying one
/// violation per rule into the real tree and require every rule to
/// fire.  Mirrors the oracle's perturbation self-test — a healthy
/// linter makes the combined run red, and CI asserts exactly that
/// (`if ct lint --self-check; then fail`).
pub fn self_check(root: &Path) -> Result<SelfCheck> {
    let mut set = source_set(root)?;
    for (path, text) in probe_files() {
        set.files.push((path.to_string(), text.to_string()));
    }
    let report = analyze(&set);
    let mut missed = Vec::new();
    for rule in rules::RULE_IDS {
        if *rule == "doc-family-drift" {
            // probed directly: a synthetic registry key that no
            // document mentions must be flagged
            let drift = docs::family_drift(
                "key: \"__lint_probe_family__\",",
                &[("README.md", "no such family here")]);
            if drift.len() != 1 {
                missed.push(*rule);
            }
            continue;
        }
        let fired = report.violations.iter().any(|v| {
            v.rule == *rule && v.file.contains("__lint_probe")
        });
        if !fired {
            missed.push(*rule);
        }
    }
    // the tolerance-gated contract has two directions, probed on
    // tensor/__lint_probe_tolerance__.rs: the header must exempt the
    // file from the bit-identity rules (det-float-* firing means the
    // exemption is broken) while the panic family stays on
    // (panic-unwrap NOT firing means lossy code escaped panic-safety)
    let tol_probe = |rule: &str| {
        report.violations.iter().any(|v| {
            v.rule == rule && v.file.contains("__lint_probe_tolerance__")
        })
    };
    if tol_probe("det-float-reduce") || tol_probe("det-float-accum") {
        missed.push("tolerance-gated-exemption");
    }
    if !tol_probe("panic-unwrap") {
        missed.push("tolerance-gated-panic-free");
    }
    let injected = report
        .violations
        .iter()
        .filter(|v| v.file.contains("__lint_probe"))
        .count();
    Ok(SelfCheck { missed, injected, report })
}

/// The synthetic probe sources, one violation per rule family.  Paths
/// place them inside the real scopes; the `__lint_probe` marker keeps
/// them distinguishable in the combined report.
fn probe_files() -> Vec<(&'static str, &'static str)> {
    vec![
        // bit-exact + entropy scope probe (carries the header so the
        // det-* rules run; contract-header is probed separately)
        ("attention/__lint_probe_det__.rs", "\
//! ct-contract: bit-exact
use std::collections::HashMap;
fn probe(xs: &[f32], seed: u64) -> f32 {
    let _t = std::time::Instant::now();
    let _s = seed ^ 0x9E37;
    let mut acc = vec![0.0f32; 4];
    for (i, x) in xs.iter().enumerate() {
        acc[i % 4] += x * 2.0;
    }
    xs.iter().sum()
}
// ct-lint: allow(det-entropy)
// ct-lint: allow(no-such-rule, reason = \"probe\")
"),
        // header probe: in bit scope, no header
        ("attention/__lint_probe_header__.rs",
         "fn probe_header() {}\n"),
        // tolerance-gated probe: the header must exempt the float
        // reduction from det-float-reduce, but the unwrap must still
        // fire panic-unwrap (tolerance-gated implies panic-free)
        ("tensor/__lint_probe_tolerance__.rs", "\
//! ct-contract: tolerance-gated
fn probe(xs: &[f32]) -> f32 {
    let t: f32 = xs.iter().sum();
    t + xs.first().unwrap()
}
"),
        // panic + wire scope probe
        ("server/__lint_probe_panic__.rs", "\
fn probe(v: Vec<u64>, i: usize) -> u64 {
    let a = v.first().unwrap();
    let b = v.iter().next().expect(\"probe\");
    if *a > *b {
        panic!(\"probe\");
    }
    v[i]
}
fn probe_wire() -> Vec<(&'static str, u64)> {
    vec![(\"__lint_probe_field__\", 1)]
}
"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set(files: Vec<(&str, &str)>) -> SourceSet {
        SourceSet {
            files: files
                .into_iter()
                .map(|(p, t)| (p.to_string(), t.to_string()))
                .collect(),
            docs: vec![("README.md".to_string(), String::new()),
                       ("docs/ARCHITECTURE.md".to_string(),
                        String::new())],
            wire_allow: vec!["id".to_string()],
        }
    }

    #[test]
    fn scopes() {
        assert!(bit_scope("attention/full.rs"));
        assert!(bit_scope("tensor/gemm.rs"));
        assert!(!bit_scope("coordinator/gateway.rs"));
        assert!(panic_scope("server/mod.rs"));
        assert!(panic_scope("attention/sharded.rs"));
        assert!(panic_scope("coordinator/gateway.rs"));
        assert!(!panic_scope("coordinator/trainer.rs"));
        assert!(!panic_scope("attention/full.rs"));
        assert!(!entropy_scope("prng/mod.rs"));
        assert!(entropy_scope("main.rs"));
        assert!(wire_scope("server/mod.rs"));
        assert!(!wire_scope("coordinator/gateway.rs"));
    }

    #[test]
    fn bit_rules_need_the_header() {
        // without the header only contract-header fires; the det
        // rules activate once the file opts in
        let bare = tiny_set(vec![(
            "attention/k.rs",
            "fn f(xs: &[f32]) -> f32 { xs.iter().sum() }\n")]);
        let rep = analyze(&bare);
        assert_eq!(rep.violations.len(), 1);
        assert_eq!(rep.violations[0].rule, "contract-header");

        let opted = tiny_set(vec![(
            "attention/k.rs",
            "//! ct-contract: bit-exact\n\
             fn f(xs: &[f32]) -> f32 { xs.iter().sum() }\n")]);
        let rep = analyze(&opted);
        assert_eq!(rep.violations.len(), 1);
        assert_eq!(rep.violations[0].rule, "det-float-reduce");
        assert_eq!(rep.violations[0].file, "rust/src/attention/k.rs");
    }

    #[test]
    fn tolerance_gated_header_satisfies_bit_scope() {
        // the header is accepted in place of bit-exact, exempts the
        // float reduction, and keeps the panic family on
        let set = tiny_set(vec![(
            "tensor/q.rs",
            "//! ct-contract: tolerance-gated\n\
             fn f(xs: &[f32]) -> f32 {\n\
                 let t: f32 = xs.iter().sum();\n\
                 t + xs.first().unwrap()\n\
             }\n")]);
        let rep = analyze(&set);
        let rules: Vec<&str> =
            rep.violations.iter().map(|v| v.rule.as_str()).collect();
        assert!(!rules.contains(&"contract-header"), "{rules:?}");
        assert!(!rules.contains(&"det-float-reduce"), "{rules:?}");
        assert!(rules.contains(&"panic-unwrap"), "{rules:?}");
    }

    #[test]
    fn unknown_contract_names_are_flagged() {
        let set = tiny_set(vec![(
            "tensor/q.rs",
            "//! ct-contract: bit-exact, tollerance-gated\n\
             fn f() {}\n")]);
        let rep = analyze(&set);
        let headers: Vec<_> = rep.violations.iter()
            .filter(|v| v.rule == "contract-header").collect();
        assert_eq!(headers.len(), 1);
        assert!(headers[0].msg.contains("tollerance-gated"),
                "{}", headers[0].msg);
    }

    #[test]
    fn tolerance_gated_does_not_satisfy_panic_scope() {
        // in server/ the panic-free header is still mandatory — the
        // bit-scope alternative doesn't leak into the serving scope
        let set = tiny_set(vec![(
            "server/x.rs",
            "//! ct-contract: tolerance-gated\n\
             fn f() {}\n")]);
        let rep = analyze(&set);
        assert!(rep.violations.iter()
                .any(|v| v.rule == "contract-header"));
    }

    #[test]
    fn suppression_with_reason_moves_to_suppressed() {
        let set = tiny_set(vec![(
            "attention/k.rs",
            "//! ct-contract: bit-exact\n\
             fn f(xs: &[f32]) -> f32 {\n\
                 // ct-lint: allow(det-float-reduce, reason = \"pinned\")\n\
                 xs.iter().sum()\n\
             }\n")]);
        let rep = analyze(&set);
        assert!(rep.passed(), "violations: {:?}", rep.violations);
        assert_eq!(rep.suppressions.len(), 1);
        assert_eq!(rep.suppressions[0].reason, "pinned");
    }

    #[test]
    fn reasonless_suppression_is_itself_a_violation() {
        let set = tiny_set(vec![(
            "server/x.rs",
            "//! ct-contract: panic-free\n\
             fn f(v: Vec<u8>) -> u8 {\n\
                 // ct-lint: allow(panic-unwrap)\n\
                 *v.first().unwrap()\n\
             }\n")]);
        let rep = analyze(&set);
        let rules: Vec<&str> =
            rep.violations.iter().map(|v| v.rule.as_str()).collect();
        // the directive is flagged AND the unwrap still fires
        assert!(rules.contains(&"lint-no-reason"));
        assert!(rules.contains(&"panic-unwrap"));
    }

    #[test]
    fn unknown_rule_in_directive() {
        let set = tiny_set(vec![(
            "server/x.rs",
            "//! ct-contract: panic-free\n\
             // ct-lint: allow(made-up, reason = \"x\")\n\
             fn f() {}\n")]);
        let rep = analyze(&set);
        assert!(rep.violations.iter()
                .any(|v| v.rule == "lint-unknown-rule"));
    }

    #[test]
    fn wire_rule_uses_allowlist() {
        let set = tiny_set(vec![(
            "server/x.rs",
            "//! ct-contract: panic-free\n\
             fn f() { emit(vec![(\"id\", 1), (\"rogue\", 2)]); }\n")]);
        let rep = analyze(&set);
        let wire: Vec<_> = rep.violations.iter()
            .filter(|v| v.rule == "wire-field").collect();
        assert_eq!(wire.len(), 1);
        assert!(wire[0].msg.contains("rogue"));
    }

    #[test]
    fn probes_trip_every_rule() {
        // the self-check's probe files, analyzed standalone, cover the
        // whole catalog except doc-family-drift (probed directly)
        let mut set = tiny_set(vec![]);
        set.files = probe_files()
            .into_iter()
            .map(|(p, t)| (p.to_string(), t.to_string()))
            .collect();
        set.wire_allow = vec!["id".to_string()];
        let rep = analyze(&set);
        for rule in rules::RULE_IDS {
            if *rule == "doc-family-drift" {
                continue;
            }
            assert!(rep.violations.iter().any(|v| v.rule == *rule),
                    "probe did not trip {rule}");
        }
    }
}
