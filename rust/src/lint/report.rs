//! The byte-stable `lint-report.json` artifact.
//!
//! The report is a deterministic function of the scanned sources:
//! violations and suppressions are sorted by `(file, line, rule,
//! msg)`, paths are repo-relative with forward slashes, and there are
//! no timestamps, hostnames or absolute paths — two runs over the
//! same tree produce identical bytes (tested in
//! `tests/integration_lint.rs`), so the CI artifact diffs cleanly
//! between commits, the same property the oracle and bench reports
//! already have.

use crate::jsonio::{self, obj, Value};

use super::rules::RULE_IDS;

/// One unsuppressed rule violation.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    /// Repo-relative file path (forward slashes).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id.
    pub rule: String,
    /// What fired, specifically.
    pub msg: String,
}

/// One suppressed hit — kept in the report so suppressions are
/// auditable without grepping the tree.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Suppression {
    /// Repo-relative file path (forward slashes).
    pub file: String,
    /// 1-based line of the suppressed hit.
    pub line: usize,
    /// Rule id.
    pub rule: String,
    /// The mandatory justification from the `allow(…)` directive.
    pub reason: String,
}

/// The full result of one lint pass.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// How many files the pass scanned.
    pub files_scanned: usize,
    /// Unsuppressed violations, sorted.
    pub violations: Vec<Violation>,
    /// Suppressed hits, sorted.
    pub suppressions: Vec<Suppression>,
}

impl LintReport {
    /// Green iff nothing unsuppressed fired.
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    /// Canonicalise ordering (called once by the engine before the
    /// report is rendered or returned).
    pub fn sort(&mut self) {
        self.violations.sort();
        self.violations.dedup();
        self.suppressions.sort();
        self.suppressions.dedup();
    }

    /// Render as a `jsonio` document.
    pub fn to_value(&self) -> Value {
        let rules: Vec<Value> =
            RULE_IDS.iter().map(|r| Value::from(*r)).collect();
        let violations: Vec<Value> = self
            .violations
            .iter()
            .map(|v| obj(vec![
                ("rule", v.rule.as_str().into()),
                ("file", v.file.as_str().into()),
                ("line", v.line.into()),
                ("msg", v.msg.as_str().into()),
            ]))
            .collect();
        let suppressions: Vec<Value> = self
            .suppressions
            .iter()
            .map(|s| obj(vec![
                ("rule", s.rule.as_str().into()),
                ("file", s.file.as_str().into()),
                ("line", s.line.into()),
                ("reason", s.reason.as_str().into()),
            ]))
            .collect();
        obj(vec![
            ("version", 1i64.into()),
            ("tool", "ct lint".into()),
            ("files_scanned", self.files_scanned.into()),
            ("rules", Value::Arr(rules)),
            ("violation_count", self.violations.len().into()),
            ("violations", Value::Arr(violations)),
            ("suppressed_count", self.suppressions.len().into()),
            ("suppressions", Value::Arr(suppressions)),
            ("passed", self.passed().into()),
        ])
    }

    /// The byte-stable pretty rendering written to
    /// `lint-report.json`.
    pub fn render(&self) -> String {
        jsonio::to_string_pretty(&self.to_value())
    }

    /// Human console summary (one line per violation, `file:line`
    /// first so terminals link them).
    pub fn console(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{}:{}: [{}] {}\n",
                                  v.file, v.line, v.rule, v.msg));
        }
        out.push_str(&format!(
            "ct lint: {} file(s), {} violation(s), {} suppressed — {}\n",
            self.files_scanned,
            self.violations.len(),
            self.suppressions.len(),
            if self.passed() { "PASS" } else { "FAIL" }));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        let mut r = LintReport {
            files_scanned: 2,
            violations: vec![
                Violation { file: "b.rs".into(), line: 9,
                            rule: "panic-unwrap".into(),
                            msg: "x".into() },
                Violation { file: "a.rs".into(), line: 3,
                            rule: "det-entropy".into(),
                            msg: "y".into() },
            ],
            suppressions: vec![Suppression {
                file: "a.rs".into(), line: 7,
                rule: "det-seed-arith".into(),
                reason: "because".into(),
            }],
        };
        r.sort();
        r
    }

    #[test]
    fn sorted_and_deterministic() {
        let r = sample();
        assert_eq!(r.violations[0].file, "a.rs");
        assert_eq!(r.render(), sample().render());
        assert!(r.render().ends_with('\n'));
    }

    #[test]
    fn roundtrips_through_jsonio() {
        let r = sample();
        let doc = crate::jsonio::parse(&r.render()).expect("parses");
        assert_eq!(doc.get("violation_count").as_usize(), Some(2));
        assert_eq!(doc.get("passed").as_bool(), Some(false));
        assert_eq!(doc.get("suppressed_count").as_usize(), Some(1));
    }

    #[test]
    fn empty_report_passes() {
        let r = LintReport::default();
        assert!(r.passed());
        assert!(r.console().contains("PASS"));
    }
}
