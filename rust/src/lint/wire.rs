//! `wire-field`: the JSON wire surface as a reviewed allowlist.
//!
//! PR 8 settled the protocol discipline — byte-stable field names,
//! optional fields emitted only when true, lenient parse on the read
//! side.  This rule makes the *write* side checkable: every field name
//! emitted as a `("name", value)` tuple in `server/` (the gateway
//! JSON-lines protocol) or `attention/sharded.rs` (the shard wire
//! header) must appear in the checked-in `lint/wire-fields.json`
//! allowlist.  Adding or renaming a protocol field therefore shows up
//! as an explicit allowlist diff a reviewer has to approve — and a
//! typo'd field name fails CI instead of silently forking the
//! protocol.
//!
//! The matcher keys on the `jsonio` emission idiom: a `("name",`
//! tuple opener whose `(` is not a call (preceded by start-of-line,
//! whitespace, `[`, `(`, `,` or `=`), so `obj(vec![("id", …)])` and
//! `fields.push(("lens", …))` match while `format!("…")`, `get("id")`
//! and `anyhow!("…")` do not.

use super::rules::Hit;
use super::scan::FileScan;
use crate::jsonio;

/// The checked-in allowlist, embedded at compile time so the binary
/// and the reviewed file can never diverge.
pub const WIRE_FIELDS_JSON: &str = include_str!("wire-fields.json");

/// Parse an allowlist document (`{"version": 1, "fields": [...]}`)
/// into its field names.  Returns `None` on a malformed document.
pub fn parse_allowlist(text: &str) -> Option<Vec<String>> {
    let doc = jsonio::parse(text).ok()?;
    let fields = doc.get("fields").as_arr()?;
    let mut out = Vec::with_capacity(fields.len());
    for f in fields {
        out.push(f.as_str()?.to_string());
    }
    Some(out)
}

/// Extract every emitted wire field name from one line.  Returns
/// `(name, column)` pairs; the caller checks them against the
/// allowlist.
pub fn emitted_fields(fs: &FileScan, i: usize) -> Vec<(String, usize)> {
    let code = fs.code_lines[i].as_bytes();
    let raw = &fs.raw_lines[i];
    let mut out = Vec::new();
    let mut j = 0usize;
    while j < code.len() {
        if code[j] != b'(' {
            j += 1;
            continue;
        }
        // predecessor must not be a call target or macro bang
        let pred = fs.code_lines[i][..j]
            .trim_end()
            .bytes()
            .last();
        let callish = matches!(pred,
            Some(b) if b.is_ascii_alphanumeric() || b == b'_'
                || b == b'!' || b == b'"' || b == b'>' || b == b')');
        if callish {
            j += 1;
            continue;
        }
        // expect: ( ws* " … " ws* ,
        let mut k = j + 1;
        while k < code.len() && code[k].is_ascii_whitespace() {
            k += 1;
        }
        if k >= code.len() || code[k] != b'"' {
            j += 1;
            continue;
        }
        let open = k;
        let mut close = open + 1;
        while close < code.len() && code[close] != b'"' {
            close += 1;
        }
        if close >= code.len() {
            j += 1;
            continue;
        }
        let mut after = close + 1;
        while after < code.len() && code[after].is_ascii_whitespace() {
            after += 1;
        }
        if after >= code.len() || code[after] != b',' {
            j = close + 1;
            continue;
        }
        // positions are preserved between code and raw views, so the
        // blanked string contents can be read back from the raw line
        let name = raw
            .get(open + 1..close)
            .unwrap_or("")
            .to_string();
        if is_ident(&name) {
            out.push((name, open + 1));
        }
        j = close + 1;
    }
    out
}

/// `[A-Za-z_][A-Za-z0-9_]*` — field-name shaped.
fn is_ident(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Run the wire-field rule over one line of a wire-surface file.
pub fn wire_field(fs: &FileScan, i: usize, allow: &[String]) -> Vec<Hit> {
    emitted_fields(fs, i)
        .into_iter()
        .filter(|(name, _)| !allow.iter().any(|a| a == name))
        .map(|(name, _)| Hit {
            rule: "wire-field",
            line: i + 1,
            msg: format!("wire field `{name}` is not in \
                          lint/wire-fields.json (protocol fields are \
                          reviewed diffs)"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> FileScan {
        FileScan::new("server/mod.rs", src)
    }

    #[test]
    fn embedded_allowlist_parses() {
        let fields = parse_allowlist(WIRE_FIELDS_JSON)
            .unwrap_or_default();
        assert!(fields.iter().any(|f| f == "id"));
        assert!(fields.iter().any(|f| f == "session"));
        // sorted and unique — the file is a reviewed artifact
        let mut sorted = fields.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(fields, sorted);
    }

    #[test]
    fn extracts_tuple_fields_not_calls() {
        let fs = scan("let v = obj(vec![\n\
                       (\"id\", id.into()),\n\
                       (\"error\", format!(\"bad: {e}\").into()),\n\
                       ]);\n\
                       fields.push((\"lens\", lens.into()));\n\
                       let x = req.get(\"id\");\n\
                       let m = anyhow!(\"no {name:?}\");");
        assert_eq!(emitted_fields(&fs, 1),
                   vec![("id".to_string(), 2)]);
        assert_eq!(emitted_fields(&fs, 2).len(), 1);
        assert_eq!(emitted_fields(&fs, 4),
                   vec![("lens".to_string(), 14)]);
        assert!(emitted_fields(&fs, 5).is_empty());
        assert!(emitted_fields(&fs, 6).is_empty());
    }

    #[test]
    fn unlisted_field_is_a_hit() {
        let fs = scan("(\"brand_new_field\", 1.into()),");
        let allow = vec!["id".to_string()];
        let hits = wire_field(&fs, 0, &allow);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].msg.contains("brand_new_field"));
        let ok = wire_field(&fs, 0,
                            &["brand_new_field".to_string()]);
        assert!(ok.is_empty());
    }
}
