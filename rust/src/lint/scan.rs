//! Source scanner: the lexical substrate every lint rule runs on.
//!
//! `ct lint` deliberately does not parse Rust — a full grammar (syn,
//! proc-macro) would be a heavyweight dependency for what the contract
//! rules actually need, which is *lexical* truth: where strings and
//! comments are (so patterns never fire inside them), which lines sit
//! under `#[cfg(test)]` / `#[test]` scope, which lines are inside a
//! loop body, and which suppression directives are in force.  The
//! scanner produces exactly that, position-preserving, so rule
//! matchers index the original text by the same offsets.
//!
//! Position preservation is the load-bearing property: every blanked
//! region (string contents, comment bodies) is replaced byte-for-byte
//! with spaces, newlines kept, so `code_lines[i]` and `raw_lines[i]`
//! always have identical lengths and column offsets.  A matcher finds
//! a span in the code view and reads its text from the raw view.

use std::fmt;

/// A suppression directive parsed from a comment:
/// `ct-lint: allow(<rule>, reason = "...")`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// 1-based line the directive was written on.
    pub line: usize,
    /// Rule id being suppressed.
    pub rule: String,
    /// Mandatory justification (empty string when the author omitted
    /// it — the engine turns that into a `lint-no-reason` violation).
    pub reason: String,
    /// `true` for `//!` (file-scope) directives, `false` for `//`
    /// (line-scope) directives.
    pub file_scope: bool,
}

/// One scanned source file, ready for rule matching.
pub struct FileScan {
    /// Repo-relative path with forward slashes (stable across hosts).
    pub path: String,
    /// Original text, split into lines.
    pub raw_lines: Vec<String>,
    /// Code view: same shape as `raw_lines` with string contents,
    /// comments and char literals blanked to spaces (delimiting quotes
    /// kept, so `("` patterns survive).
    pub code_lines: Vec<String>,
    /// `in_test[i]` — line `i+1` is inside a `#[cfg(test)]` or
    /// `#[test]` brace scope (including the attribute lines).
    pub in_test: Vec<bool>,
    /// `in_loop[i]` — line `i+1` is inside a `for`/`while`/`loop`
    /// body.
    pub in_loop: Vec<bool>,
    /// Every suppression directive in the file, in source order.
    pub allows: Vec<Allow>,
    /// Contract names declared by `//! ct-contract:` header lines
    /// (first 40 lines), e.g. `bit-exact`, `panic-free`.
    pub contracts: Vec<String>,
}

impl fmt::Debug for FileScan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FileScan({}, {} lines)", self.path, self.raw_lines.len())
    }
}

impl FileScan {
    /// Scan one file.  `path` must already be repo-relative with
    /// forward slashes.
    pub fn new(path: &str, text: &str) -> Self {
        let (code, comments) = blank_noncode(text);
        let raw_lines: Vec<String> =
            text.split('\n').map(str::to_string).collect();
        let code_lines: Vec<String> =
            code.split('\n').map(str::to_string).collect();
        let in_test = test_scope(&code_lines);
        let in_loop = loop_scope(&code_lines);
        let allows = parse_allows(&comments);
        let contracts = parse_contracts(&raw_lines);
        FileScan { path: path.to_string(), raw_lines, code_lines,
                   in_test, in_loop, allows, contracts }
    }

    /// Does this file declare the named contract in its header?
    pub fn has_contract(&self, name: &str) -> bool {
        self.contracts.iter().any(|c| c == name)
    }

    /// The reason of an in-force suppression for `rule` at 1-based
    /// `line`, if any.  A directive applies to its own line (trailing
    /// form) or, when written on a comment-only line, to the next line
    /// that carries code (standalone form; consecutive standalone
    /// directives stack).  File-scope (`//!`) directives apply
    /// everywhere in the file.  Directives without a reason never
    /// suppress — they are themselves violations.
    pub fn suppression(&self, rule: &str, line: usize) -> Option<&str> {
        for a in &self.allows {
            if a.rule != rule || a.reason.is_empty() {
                continue;
            }
            if a.file_scope {
                return Some(&a.reason);
            }
            if a.line == line {
                return Some(&a.reason);
            }
            // standalone: directive on a codeless line covers the next
            // code line; anything codeless in between is transparent
            if a.line < line && self.codeless(a.line) {
                let covers = (a.line + 1..line)
                    .all(|l| self.codeless(l));
                if covers {
                    return Some(&a.reason);
                }
            }
        }
        None
    }

    /// Line carries no code (blank, or comment-only).
    fn codeless(&self, line: usize) -> bool {
        self.code_lines
            .get(line - 1)
            .is_none_or(|l| l.trim().is_empty())
    }
}

/// Blank string/char-literal contents and comments out of `text`,
/// preserving byte positions; returns the code view plus every line
/// comment keyed by 1-based line.
///
/// Handles nested block comments, escaped quotes, raw strings
/// (`r"…"`, `r#"…"#`), and distinguishes char literals from
/// lifetimes.  Delimiting `"` quotes are kept so tuple-literal
/// patterns like `("name",` remain matchable in the code view.
pub fn blank_noncode(text: &str) -> (String, Vec<(usize, String)>) {
    let b = text.as_bytes();
    let n = b.len();
    let mut out = String::with_capacity(n);
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            out.push('\n');
            line += 1;
            i += 1;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push((line, text[i..j].to_string()));
            for _ in i..j {
                out.push(' ');
            }
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            for k in i..j {
                if b[k] == b'\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
            }
            i = j;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            out.push('"');
            let body_end = j.saturating_sub(1).max(i + 1);
            for k in i + 1..body_end {
                if b[k] == b'\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
            }
            if j > i + 1 {
                out.push('"');
            }
            i = j.min(n);
        } else if c == b'r'
            && i + 1 < n
            && (b[i + 1] == b'"' || b[i + 1] == b'#')
        {
            // raw string r"…" / r#"…"# — blank it entirely
            let mut hashes = 0usize;
            let mut j = i + 1;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let close: String =
                    std::iter::once('"')
                        .chain(std::iter::repeat_n('#', hashes))
                        .collect();
                let end = text[j..]
                    .find(&close)
                    .map_or(n, |p| j + p + close.len());
                for k in i..end {
                    if b[k] == b'\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                }
                i = end;
            } else {
                out.push(c as char);
                i += 1;
            }
        } else if c == b'\'' {
            // char literal vs lifetime: a literal closes within a few
            // bytes with a matching quote
            let lit_len = char_literal_len(&b[i..]);
            if let Some(len) = lit_len {
                out.push('\'');
                for _ in 0..len - 2 {
                    out.push(' ');
                }
                out.push('\'');
                i += len;
            } else {
                out.push('\'');
                i += 1;
            }
        } else {
            out.push(c as char);
            i += 1;
        }
    }
    (out, comments)
}

/// Length of a char literal starting at `b[0] == b'\''`, or `None`
/// when it is a lifetime.
fn char_literal_len(b: &[u8]) -> Option<usize> {
    if b.len() < 3 {
        return None;
    }
    if b[1] == b'\\' {
        // escaped: scan to the closing quote (covers \n, \x7f, \u{…})
        for (j, &c) in b.iter().enumerate().skip(2) {
            if c == b'\'' {
                return Some(j + 1);
            }
            if c == b'\n' || j > 12 {
                break;
            }
        }
        None
    } else if b[2] == b'\'' && b[1] != b'\'' {
        Some(3)
    } else {
        None
    }
}

/// Mark every line inside a `#[cfg(test)]` / `#[test]` brace scope.
///
/// Brace depth is tracked over the code view; seeing a test attribute
/// arms a pending flag that transfers to the next `{` opened (the item
/// body).  A `;` at the attribute's depth disarms it (`mod tests;`
/// out-of-line form).  Attribute lines themselves count as test scope
/// so signatures between attribute and body are excluded too.
fn test_scope(code_lines: &[String]) -> Vec<bool> {
    let mut res = vec![false; code_lines.len()];
    let mut stack: Vec<bool> = Vec::new();
    let mut pending = false;
    for (idx, lt) in code_lines.iter().enumerate() {
        let start_test = stack.iter().any(|&t| t);
        let mut became = false;
        if is_test_attr_line(lt) {
            pending = true;
        }
        for ch in lt.chars() {
            match ch {
                '{' => {
                    stack.push(pending);
                    pending = false;
                    if stack.iter().any(|&t| t) {
                        became = true;
                    }
                }
                '}' => {
                    stack.pop();
                }
                ';' if pending && stack.is_empty() => pending = false,
                _ => {}
            }
        }
        res[idx] = start_test || became || pending;
    }
    res
}

/// Does this code line carry a `#[cfg(test)]` or `#[test]` attribute?
fn is_test_attr_line(lt: &str) -> bool {
    let squished: String =
        lt.chars().filter(|c| !c.is_whitespace()).collect();
    squished.contains("#[cfg(test)]") || squished.contains("#[test]")
}

/// Mark every line inside a `for` / `while` / `loop` body, by tagging
/// each opened brace with whether the code chunk since the last
/// `{`/`}`/`;` contained a loop keyword.
fn loop_scope(code_lines: &[String]) -> Vec<bool> {
    let mut res = vec![false; code_lines.len()];
    let mut stack: Vec<bool> = Vec::new();
    let mut chunk = String::new();
    for (idx, lt) in code_lines.iter().enumerate() {
        if stack.iter().any(|&l| l) {
            res[idx] = true;
        }
        for ch in lt.chars() {
            match ch {
                '{' => {
                    stack.push(has_loop_keyword(&chunk));
                    chunk.clear();
                    if stack.iter().any(|&l| l) {
                        res[idx] = true;
                    }
                }
                '}' => {
                    stack.pop();
                    chunk.clear();
                }
                ';' => chunk.clear(),
                c => chunk.push(c),
            }
        }
        chunk.push(' ');
    }
    res
}

/// Whole-word `for` / `while` / `loop` in a code chunk.
fn has_loop_keyword(chunk: &str) -> bool {
    let mut word = String::new();
    for c in chunk.chars().chain(std::iter::once(' ')) {
        if c.is_alphanumeric() || c == '_' {
            word.push(c);
        } else {
            if word == "for" || word == "while" || word == "loop" {
                return true;
            }
            word.clear();
        }
    }
    false
}

/// Parse every `ct-lint: allow(rule, reason = "…")` directive out of
/// the file's comments.  Only `//` and `//!` comments carry
/// directives; `///` doc comments never do, so rule documentation can
/// show the syntax without activating it.
fn parse_allows(comments: &[(usize, String)]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (line, c) in comments {
        let body = c.trim_start();
        let (file_scope, rest) = if let Some(r) = body.strip_prefix("//!")
        {
            (true, r)
        } else if body.starts_with("///") {
            continue;
        } else if let Some(r) = body.strip_prefix("//") {
            (false, r)
        } else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(args) = rest
            .strip_prefix("ct-lint:")
            .map(str::trim_start)
            .and_then(|r| r.strip_prefix("allow("))
        else {
            continue;
        };
        let Some(close) = args.rfind(')') else { continue };
        let args = &args[..close];
        let (rule, reason) = match args.find(',') {
            None => (args.trim(), String::new()),
            Some(comma) => {
                let rule = args[..comma].trim();
                let tail = args[comma + 1..].trim();
                let reason = tail
                    .strip_prefix("reason")
                    .map(str::trim_start)
                    .and_then(|t| t.strip_prefix('='))
                    .map(str::trim)
                    .and_then(|t| {
                        t.strip_prefix('"')
                            .and_then(|t| t.strip_suffix('"'))
                    })
                    .unwrap_or("")
                    .to_string();
                (rule, reason)
            }
        };
        out.push(Allow {
            line: *line,
            rule: rule.to_string(),
            reason,
            file_scope,
        });
    }
    out
}

/// Contract names from `//! ct-contract: a, b` header lines (scanned
/// over the first 40 lines).
fn parse_contracts(raw_lines: &[String]) -> Vec<String> {
    let mut out = Vec::new();
    for l in raw_lines.iter().take(40) {
        let t = l.trim_start();
        if let Some(rest) = t
            .strip_prefix("//!")
            .map(str::trim_start)
            .and_then(|r| r.strip_prefix("ct-contract:"))
        {
            for name in rest.split(',') {
                let name = name.trim();
                if !name.is_empty() {
                    out.push(name.to_string());
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanking_preserves_positions() {
        let src = "let a = \"hi // not a comment\"; // real\nlet b = 1;";
        let (code, comments) = blank_noncode(src);
        assert_eq!(code.len(), src.len());
        assert!(code.contains("let a = \"                  \";"));
        assert!(!code.contains("real"));
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].0, 1);
        assert!(comments[0].1.contains("real"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "a /* x /* y */ z */ b\nlet r = r#\"un\"closed\"#;";
        let (code, _) = blank_noncode(src);
        assert!(code.starts_with("a "));
        assert!(code.contains(" b"));
        assert!(!code.contains('y'));
        assert!(!code.contains("un"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let (code, _) = blank_noncode("let c = 'x'; fn f<'a>(v: &'a u8) {}");
        assert!(!code.contains('x'));
        assert!(code.contains("<'a>"));
        let (code2, _) = blank_noncode("let nl = '\\n';");
        assert!(!code2.contains('n') || code2.contains("nl"));
    }

    #[test]
    fn test_scope_covers_cfg_test_mod() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}";
        let fs = FileScan::new("x.rs", src);
        assert!(!fs.in_test[0]);
        assert!(fs.in_test[1] && fs.in_test[2] && fs.in_test[3]
                && fs.in_test[4]);
        assert!(!fs.in_test[5]);
    }

    #[test]
    fn test_scope_covers_test_fn_items() {
        let src = "#[test]\nfn prop() {\n    body();\n}\nfn live() {}";
        let fs = FileScan::new("x.rs", src);
        assert!(fs.in_test[0] && fs.in_test[1] && fs.in_test[2]);
        assert!(!fs.in_test[4]);
    }

    #[test]
    fn loop_scope_tracks_bodies() {
        let src = "fn f() {\n    for i in 0..3 {\n        x += y * 2.0;\n    }\n    x += 1;\n}";
        let fs = FileScan::new("x.rs", src);
        assert!(fs.in_loop[2]);
        assert!(!fs.in_loop[4]);
    }

    #[test]
    fn allow_directive_forms() {
        let src = "\
//! ct-contract: bit-exact
//! ct-lint: allow(det-entropy, reason = \"file-wide ok\")
fn f() {
    // ct-lint: allow(panic-unwrap, reason = \"standalone\")
    a.unwrap();
    b.unwrap(); // ct-lint: allow(panic-unwrap, reason = \"trailing\")
    // ct-lint: allow(panic-expect)
    c.expect(\"no reason given\");
}";
        let fs = FileScan::new("x.rs", src);
        assert!(fs.has_contract("bit-exact"));
        assert_eq!(fs.suppression("det-entropy", 5), Some("file-wide ok"));
        assert_eq!(fs.suppression("panic-unwrap", 5), Some("standalone"));
        assert_eq!(fs.suppression("panic-unwrap", 6), Some("trailing"));
        // reasonless directive must not suppress
        assert_eq!(fs.suppression("panic-expect", 8), None);
        let no_reason: Vec<_> =
            fs.allows.iter().filter(|a| a.reason.is_empty()).collect();
        assert_eq!(no_reason.len(), 1);
        assert_eq!(no_reason[0].rule, "panic-expect");
    }

    #[test]
    fn doc_comment_examples_are_inert() {
        let src = "/// ct-lint: allow(panic-unwrap, reason = \"doc\")\nfn f() { a.unwrap(); }";
        let fs = FileScan::new("x.rs", src);
        assert!(fs.allows.is_empty());
    }
}
