//! Launcher configuration: JSON config files merged with CLI overrides,
//! plus a tiny stderr logger (the `log` facade's backend).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::jsonio::parse;

/// Global run configuration shared by the `ct` subcommands.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: String,
    pub checkpoints_dir: String,
    pub results_dir: String,
    pub seed: u64,
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            checkpoints_dir: "target/checkpoints".into(),
            results_dir: "target/bench-results".into(),
            seed: 0,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        }
    }
}

impl RunConfig {
    /// Load from a JSON file; unknown keys are rejected (typo safety).
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let v = parse(&text).map_err(|e| anyhow!("config: {e}"))?;
        let mut cfg = Self::default();
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow!("config root must be an object"))?;
        for (k, val) in obj {
            match k.as_str() {
                "artifacts_dir" => {
                    cfg.artifacts_dir =
                        val.as_str().unwrap_or(&cfg.artifacts_dir).into()
                }
                "checkpoints_dir" => {
                    cfg.checkpoints_dir =
                        val.as_str().unwrap_or(&cfg.checkpoints_dir).into()
                }
                "results_dir" => {
                    cfg.results_dir =
                        val.as_str().unwrap_or(&cfg.results_dir).into()
                }
                "seed" => cfg.seed = val.as_i64().unwrap_or(0) as u64,
                "threads" => {
                    cfg.threads = val.as_usize().unwrap_or(cfg.threads)
                }
                other => return Err(anyhow!("unknown config key {other:?}")),
            }
        }
        Ok(cfg)
    }

    pub fn ensure_dirs(&self) -> Result<()> {
        std::fs::create_dir_all(&self.checkpoints_dir)?;
        std::fs::create_dir_all(&self.results_dir)?;
        Ok(())
    }

    pub fn checkpoint_path(&self, model: &str) -> std::path::PathBuf {
        Path::new(&self.checkpoints_dir).join(format!("{model}.ckpt"))
    }
}

/// `log` backend printing `level target: message` to stderr.
struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }
    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:>5}] {}", record.level(), record.args());
        }
    }
    fn flush(&self) {}
}

/// Install the logger once (idempotent).
pub fn init_logging(verbose: bool) {
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if verbose {
        log::LevelFilter::Debug
    } else {
        log::LevelFilter::Info
    });
}

/// Find the repo root by walking up from cwd — lets benches run from any
/// directory.  `artifacts/` or `.git/` mark the root; a bare `Cargo.toml`
/// is only a fallback (cargo sets cwd to `rust/`, which has its own
/// `Cargo.toml` but is one level below the repo root).
pub fn find_repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut cargo_fallback: Option<std::path::PathBuf> = None;
    for _ in 0..6 {
        if dir.join("artifacts").exists() || dir.join(".git").exists() {
            return dir;
        }
        if cargo_fallback.is_none() && dir.join("Cargo.toml").exists() {
            cargo_fallback = Some(dir.clone());
        }
        if !dir.pop() {
            break;
        }
    }
    cargo_fallback.unwrap_or_else(|| ".".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = RunConfig::default();
        assert!(c.threads >= 1);
        assert_eq!(c.artifacts_dir, "artifacts");
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("ct-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"seed": 7, "threads": 2,
                               "artifacts_dir": "art"}"#).unwrap();
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.threads, 2);
        assert_eq!(c.artifacts_dir, "art");
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn unknown_keys_rejected() {
        let dir = std::env::temp_dir().join("ct-config-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, r#"{"sneed": 7}"#).unwrap();
        assert!(RunConfig::from_file(&p).is_err());
        std::fs::remove_file(p).unwrap();
    }
}
