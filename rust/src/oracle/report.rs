//! ct-contract: panic-free
//!
//! `oracle-report.json`: the machine-readable verdict of a replay (and
//! optionally a perf-gate) run.
//!
//! The report is deliberately timestamp-free and field-order-stable
//! (jsonio emits insertion order), so two green runs of the same build
//! produce byte-identical reports — the report file itself can be
//! diffed, archived, or checked into a triage issue without noise.
//!
//! Layout:
//!
//! ```json
//! {
//!   "format_version": 1,
//!   "tool": "ct oracle",
//!   "status": "green" | "red",
//!   "fixtures": [
//!     {
//!       "name": "...", "status": "pass" | "fail",
//!       "checked_responses": N, "mismatched_elems": N,
//!       "first_diff": null | {"response": i, "elem": j,
//!                             "got_bits": "hex", "want_bits": "hex"},
//!       "failures": ["..."], "notes": ["..."]
//!     }, ...
//!   ],
//!   "perf": { ... merged by `ct oracle perf-gate`, see perf.rs ... }
//! }
//! ```
//!
//! `status` is red iff any fixture failed **or** the merged perf gate
//! failed.  Frame bits in `first_diff` are reported as the raw f32 bit
//! patterns (hex) rather than decimals — the diff contract is
//! bit-exactness, and `0x3f800001` vs `0x3f800000` says more than
//! `1.0000001 != 1.0`.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::jsonio::{self, obj, Value};

use super::fixture::FORMAT_VERSION;

/// Location of the first differing frame element, by response index and
/// element offset within that response's output block.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameDiff {
    pub response: usize,
    pub elem: usize,
    pub got_bits: u32,
    pub want_bits: u32,
}

impl FrameDiff {
    fn to_value(&self) -> Value {
        obj(vec![
            ("response", self.response.into()),
            ("elem", self.elem.into()),
            ("got_bits", format!("{:08x}", self.got_bits).into()),
            ("want_bits", format!("{:08x}", self.want_bits).into()),
        ])
    }
}

/// Verdict for one fixture.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FixtureResult {
    pub name: String,
    pub passed: bool,
    /// Responses compared (0 when the fixture failed to load or run).
    pub checked_responses: usize,
    /// Total frame elements whose bits differed.
    pub mismatched_elems: usize,
    pub first_diff: Option<FrameDiff>,
    /// Gating failures: meta mismatches, counter drift, run errors.
    pub failures: Vec<String>,
    /// Non-gating annotations (e.g. the injected-perturbation marker).
    pub notes: Vec<String>,
}

impl FixtureResult {
    /// A result that never ran (load/run error) — always a failure.
    pub fn errored(name: &str, err: &anyhow::Error) -> Self {
        Self {
            name: name.to_string(),
            passed: false,
            checked_responses: 0,
            mismatched_elems: 0,
            first_diff: None,
            failures: vec![format!("{err:#}")],
            notes: Vec::new(),
        }
    }

    fn to_value(&self) -> Value {
        obj(vec![
            ("name", self.name.as_str().into()),
            ("status",
             if self.passed { "pass" } else { "fail" }.into()),
            ("checked_responses", self.checked_responses.into()),
            ("mismatched_elems", self.mismatched_elems.into()),
            ("first_diff", match &self.first_diff {
                Some(d) => d.to_value(),
                None => Value::Null,
            }),
            ("failures", Value::Arr(
                self.failures.iter().map(|s| s.as_str().into())
                    .collect())),
            ("notes", Value::Arr(
                self.notes.iter().map(|s| s.as_str().into())
                    .collect())),
        ])
    }
}

/// The whole report: fixture verdicts plus an optional perf-gate
/// section merged in by `ct oracle perf-gate`.
#[derive(Debug, Clone, Default)]
pub struct OracleReport {
    pub fixtures: Vec<FixtureResult>,
    /// Pre-built perf section (`PerfGateResult::to_value()`) and
    /// whether it passed.
    pub perf: Option<(Value, bool)>,
}

impl OracleReport {
    pub fn passed(&self) -> bool {
        self.fixtures.iter().all(|f| f.passed)
            && self.perf.as_ref().map_or(true, |&(_, ok)| ok)
    }

    pub fn to_value(&self) -> Value {
        let mut v = obj(vec![
            ("format_version", (FORMAT_VERSION as usize).into()),
            ("tool", "ct oracle".into()),
            ("status",
             if self.passed() { "green" } else { "red" }.into()),
            ("fixtures", Value::Arr(
                self.fixtures.iter().map(FixtureResult::to_value)
                    .collect())),
        ]);
        if let Some((perf, _)) = &self.perf {
            v.set("perf", perf.clone());
        }
        v
    }

    /// Write the report (pretty, stable) to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, jsonio::to_string_pretty(&self.to_value()))
            .map_err(|e| anyhow!("write {}: {e}", path.display()))
    }

    /// Merge a perf-gate verdict into an existing report file (or start
    /// a fresh report when none exists), preserving the fixture section
    /// verbatim, and recompute `status`.  Returns the merged report's
    /// overall pass/fail.
    pub fn merge_perf_into(path: &Path, perf: Value, perf_ok: bool)
                           -> Result<bool> {
        let mut v = if path.exists() {
            jsonio::parse(&std::fs::read_to_string(path)?)
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?
        } else {
            obj(vec![
                ("format_version", (FORMAT_VERSION as usize).into()),
                ("tool", "ct oracle".into()),
                ("status", "green".into()),
                ("fixtures", Value::Arr(Vec::new())),
            ])
        };
        let fixtures_green = v.get("status").as_str() != Some("red");
        let ok = fixtures_green && perf_ok;
        v.set("status", if ok { "green" } else { "red" }.into());
        v.set("perf", perf);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, jsonio::to_string_pretty(&v))
            .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
        Ok(ok)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pass(name: &str) -> FixtureResult {
        FixtureResult {
            name: name.into(),
            passed: true,
            checked_responses: 4,
            mismatched_elems: 0,
            first_diff: None,
            failures: Vec::new(),
            notes: Vec::new(),
        }
    }

    #[test]
    fn status_reflects_fixtures_and_perf() {
        let mut report = OracleReport {
            fixtures: vec![pass("a"), pass("b")],
            perf: None,
        };
        assert!(report.passed());
        assert_eq!(report.to_value().get("status").as_str(),
                   Some("green"));
        report.fixtures[1].passed = false;
        report.fixtures[1].failures.push("frame diff".into());
        assert!(!report.passed());
        assert_eq!(report.to_value().get("status").as_str(),
                   Some("red"));
        report.fixtures[1] = pass("b");
        report.perf = Some((obj(vec![("status", "fail".into())]),
                            false));
        assert!(!report.passed());
    }

    #[test]
    fn report_file_is_byte_stable_and_perf_merge_recomputes_status() {
        let dir = std::env::temp_dir()
            .join(format!("ct-oracle-report-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("oracle-report.json");
        let report = OracleReport {
            fixtures: vec![pass("a"),
                           FixtureResult {
                               passed: false,
                               mismatched_elems: 1,
                               first_diff: Some(FrameDiff {
                                   response: 2,
                                   elem: 7,
                                   got_bits: 0x3f80_0001,
                                   want_bits: 0x3f80_0000,
                               }),
                               failures: vec!["frame bits".into()],
                               ..pass("b")
                           }],
            perf: None,
        };
        report.write(&path).unwrap();
        let first = std::fs::read(&path).unwrap();
        report.write(&path).unwrap();
        assert_eq!(first, std::fs::read(&path).unwrap());
        let v = jsonio::parse(
            &String::from_utf8(first).unwrap()).unwrap();
        assert_eq!(v.get("status").as_str(), Some("red"));
        let diff = v.get("fixtures").as_arr().unwrap()[1]
            .get("first_diff").clone();
        assert_eq!(diff.get("got_bits").as_str(), Some("3f800001"));

        // a green-fixture report + failing perf gate goes red on merge
        let green = OracleReport { fixtures: vec![pass("a")],
                                   perf: None };
        green.write(&path).unwrap();
        let ok = OracleReport::merge_perf_into(
            &path, obj(vec![("status", "fail".into())]), false)
            .unwrap();
        assert!(!ok);
        let v = jsonio::parse(
            &std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("status").as_str(), Some("red"));
        assert_eq!(v.get("perf").get("status").as_str(), Some("fail"));
        assert_eq!(v.get("fixtures").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
