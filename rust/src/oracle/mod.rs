//! ct-contract: panic-free
//!
//! Golden-trace oracle harness: record/replay parity for the serving
//! stack, plus the perf-regression gate.
//!
//! (Not to be confused with the `oracle-top-N` *attention kernel* in
//! [`crate::attention`] — that oracle picks top-K keys; this module is
//! the repo's regression oracle.)
//!
//! # What it pins
//!
//! `ct oracle record` drives the live [`ServingGateway`] — native
//! single-host or fanned out over freshly spawned local
//! `ct shard-worker` processes-worth of [`ShardEngine`]s — through a
//! seeded trace (ragged one-shots, multi-step decode sessions, or a
//! mix) and freezes what came back: output frames, per-response
//! metadata (bucket, span, cache-hit flags) and the deterministic
//! metric counters.  `ct oracle replay` re-runs the same specs on the
//! *current* build and diffs against the recording **bit-exactly**,
//! emitting `oracle-report.json` (see [`report`]).  Anything that
//! changes serving semantics — a kernel tweak, a batcher reorder, a
//! cache bug — turns a fixture red with the first differing f32 bit
//! pattern in hand.
//!
//! # Why replay can demand bit-exactness
//!
//! Fixture buckets always run `batch_size = 1` ([`FixtureSpec`] docs):
//! single-request flushes make every response a pure function of its
//! own request, independent of co-batching, lane count, worker count
//! and timing.  Record deliberately replays with a *different* client
//! lane count than replay ([`RECORD_LANES`] vs [`REPLAY_LANES`]), so a
//! green suite is itself evidence of composition independence.
//!
//! # Regenerability
//!
//! A fixture's requests are a pure function of its spec
//! ([`TraceSpec::generate`]), so fixtures never store inputs and any
//! fixture can be re-recorded from its header alone (`ct oracle bless`
//! re-records the standard suite in place; CI bootstrap-records any
//! missing fixture before replaying).  The one hand-auditable fixture,
//! `identity-len1`, has closed-form expected outputs
//! ([`identity_expected_frames`]) and ships checked in.
//!
//! # Perf gate
//!
//! [`perf`] compares fresh `BENCH_*.json` files against
//! `bench-baselines/` and fails CI on a >15% rows/sec regression
//! (tolerance from `oracle/tolerance-policy.json`, see [`policy`]).
//!
//! Operator guide: `docs/TESTING.md`.

pub mod fixture;
pub mod perf;
pub mod policy;
pub mod report;

pub use fixture::{fnv1a64, frames_to_bytes, identity_expected_frames,
                  pattern_value, Fixture, FixtureSpec, Manifest,
                  MetricsSnapshot, RespMeta, TraceSpec, FORMAT_VERSION};
pub use perf::{bench_doc, compare_records, run_perf_gate, self_check,
               BenchGate, PerfGateResult, RowGate, RowStatus};
pub use policy::{OutputBits, TolerancePolicy};
pub use report::{FixtureResult, FrameDiff, OracleReport};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::attention::ShardEngine;
use crate::coordinator::{replay_blocking, Bucket, GatewayOptions,
                         ServingGateway};

/// Client lanes used when recording a fixture…
pub const RECORD_LANES: usize = 4;
/// …and when replaying it.  Different on purpose: a green replay also
/// proves the bits don't depend on how the trace was spread over
/// concurrent clients.
pub const REPLAY_LANES: usize = 3;

// ---------------------------------------------------------------------------
// canonical repo locations
// ---------------------------------------------------------------------------

/// `<repo>/oracle/fixtures` — fixture headers, frames and manifest.
pub fn default_fixture_dir() -> PathBuf {
    crate::config::find_repo_root().join("oracle").join("fixtures")
}

/// `<repo>/oracle/tolerance-policy.json`.
pub fn default_policy_path() -> PathBuf {
    crate::config::find_repo_root()
        .join("oracle")
        .join("tolerance-policy.json")
}

/// `<repo>/oracle-report.json` — next to the `BENCH_*.json` drops.
pub fn default_report_path() -> PathBuf {
    crate::config::find_repo_root().join("oracle-report.json")
}

/// `<repo>/bench-baselines` — blessed perf baselines.
pub fn default_baseline_dir() -> PathBuf {
    crate::config::find_repo_root().join("bench-baselines")
}

// ---------------------------------------------------------------------------
// the standard suite
// ---------------------------------------------------------------------------

/// The checked-in fixture suite `ct oracle record`/`replay`/`bless`
/// operate on by default.  Kept deliberately small — seven fixtures
/// covering the serving matrix: identity (hand-auditable), ragged
/// masked, ragged *unmasked* (static-shape semantics: padded keys
/// participate, still deterministic at batch 1), a clustered kernel,
/// decode sessions (masking required there), sharded fan-out with a
/// mixed trace, and causal linear decode sessions (pinning the O(1)
/// recurrent-state cache path bit-for-bit).
pub fn standard_suite() -> Vec<FixtureSpec> {
    vec![
        FixtureSpec {
            name: "identity-len1".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 4,
            dv: 4,
            buckets: vec![8],
            seed: 7,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::IdentityLen1 { count: 6 },
        },
        FixtureSpec {
            name: "ragged-full-masked".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32, 64],
            seed: 11,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::Ragged {
                min_len: 3, max_len: 48, count: 24,
            },
        },
        FixtureSpec {
            name: "ragged-full-unmasked".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32, 64],
            seed: 19,
            masked: false,
            causal: false,
            shards: 0,
            trace: TraceSpec::Ragged {
                min_len: 3, max_len: 48, count: 12,
            },
        },
        FixtureSpec {
            name: "clustered-masked".into(),
            kernel: "i-clustered-4".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32, 64],
            seed: 13,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::Ragged {
                min_len: 8, max_len: 64, count: 16,
            },
        },
        FixtureSpec {
            name: "decode-sessions".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32],
            seed: 17,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::Decode {
                prefill: 6, steps: 3, step_len: 2, sessions: 3,
            },
        },
        FixtureSpec {
            name: "mixed-sharded".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32],
            seed: 23,
            masked: true,
            causal: false,
            shards: 2,
            trace: TraceSpec::Mixed {
                min_len: 3, max_len: 24, count: 10,
                prefill: 5, steps: 2, step_len: 2, sessions: 2,
            },
        },
        FixtureSpec {
            name: "linear-causal-decode".into(),
            kernel: "linear".into(),
            heads: 2,
            dk: 8,
            dv: 8,
            buckets: vec![8, 16, 32],
            seed: 29,
            masked: true,
            causal: true,
            shards: 0,
            trace: TraceSpec::Decode {
                prefill: 6, steps: 3, step_len: 2, sessions: 2,
            },
        },
    ]
}

// ---------------------------------------------------------------------------
// driving the gateway
// ---------------------------------------------------------------------------

/// A running local shard worker (the hermetic stand-in for a remote
/// `ct shard-worker` host).  Dropping without [`shutdown`] leaks the
/// accept thread for the process lifetime — call shutdown.
///
/// [`shutdown`]: ShardWorkerGuard::shutdown
pub struct ShardWorkerGuard {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ShardWorkerGuard {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Spawn `n` single-threaded shard workers on ephemeral localhost
/// ports; returns their addresses (gateway `shards` option) and the
/// guards to shut them down.
pub fn spawn_local_shard_workers(n: usize)
    -> Result<(Vec<String>, Vec<ShardWorkerGuard>)> {
    let mut addrs = Vec::with_capacity(n);
    let mut guards = Vec::with_capacity(n);
    for _ in 0..n {
        let engine = Arc::new(ShardEngine::new(1));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let (tx, rx) = mpsc::channel();
        let thread = std::thread::spawn(move || {
            let _ = crate::server::serve_shard_worker(
                engine, "127.0.0.1:0", stop2,
                move |a| { let _ = tx.send(a); });
        });
        let addr = rx
            .recv_timeout(Duration::from_secs(30))
            .map_err(|_| anyhow!("oracle shard worker failed to bind"))?;
        addrs.push(addr.to_string());
        guards.push(ShardWorkerGuard { stop, thread: Some(thread) });
    }
    Ok((addrs, guards))
}

/// What one pass of a spec through a live gateway produced.
#[derive(Debug, Clone)]
pub struct RecordedRun {
    pub responses: Vec<RespMeta>,
    pub metrics: MetricsSnapshot,
    pub frames: Vec<f32>,
}

/// Build the gateway a spec describes, replay its trace over `lanes`
/// blocking clients, and capture responses + metrics.  Pure record/
/// replay workhorse: record calls it with [`RECORD_LANES`], replay
/// with [`REPLAY_LANES`].  Sharded specs spawn their own local workers
/// for the duration of the run.
pub fn run_spec(spec: &FixtureSpec, lanes: usize) -> Result<RecordedRun> {
    let shape = spec.shape();
    let trace = spec.trace.generate(shape, spec.seed);
    let (shard_addrs, guards) = if spec.shards > 0 {
        spawn_local_shard_workers(spec.shards)?
    } else {
        (Vec::new(), Vec::new())
    };
    let buckets = spec.buckets.iter()
        // batch_size pinned to 1 — see FixtureSpec docs
        .map(|&n| Bucket::native(spec.kernel.as_str(), n, 1))
        .collect();
    let opts = GatewayOptions {
        max_wait: Duration::from_millis(1),
        seed: spec.seed,
        mask: spec.masked,
        causal: spec.causal,
        shards: shard_addrs,
        ..GatewayOptions::default()
    };
    let gw = ServingGateway::start(shape, buckets, opts)?;
    let responses = replay_blocking(&gw, trace, lanes);
    let metrics = MetricsSnapshot::capture(&gw);
    gw.shutdown();
    for g in guards {
        g.shutdown();
    }
    let mut frames = Vec::new();
    let responses = responses
        .iter()
        .map(|r| {
            frames.extend_from_slice(&r.out);
            RespMeta::from_response(r)
        })
        .collect();
    Ok(RecordedRun { responses, metrics, frames })
}

// ---------------------------------------------------------------------------
// record
// ---------------------------------------------------------------------------

/// Record one spec into an in-memory [`Fixture`].
pub fn record_spec(spec: &FixtureSpec) -> Result<Fixture> {
    let run = run_spec(spec, RECORD_LANES)?;
    Ok(Fixture {
        spec: spec.clone(),
        responses: run.responses,
        metrics: run.metrics,
        frames: run.frames,
    })
}

/// Record `specs` into `dir`, updating the manifest.  Existing
/// fixtures are kept unless `force` (that asymmetry is the whole
/// `record --missing-only` vs `bless` distinction).  Returns the names
/// actually (re-)recorded.
pub fn record_suite(dir: &std::path::Path, specs: &[FixtureSpec],
                    force: bool) -> Result<Vec<String>> {
    let mut manifest = Manifest::load(dir)?;
    let mut recorded = Vec::new();
    for spec in specs {
        if !force && Fixture::exists(dir, &spec.name) {
            manifest.add(&spec.name);
            continue;
        }
        record_spec(spec)?.save(dir)?;
        manifest.add(&spec.name);
        recorded.push(spec.name.clone());
    }
    manifest.save(dir)?;
    Ok(recorded)
}

// ---------------------------------------------------------------------------
// replay + diff
// ---------------------------------------------------------------------------

/// Flat frame offset → (response index, element offset) under the
/// recording's per-response element counts.
fn locate(fx: &Fixture, flat: usize) -> (usize, usize) {
    let mut off = 0;
    for (i, r) in fx.responses.iter().enumerate() {
        if flat < off + r.elems {
            return (i, flat - off);
        }
        off += r.elems;
    }
    (fx.responses.len(), 0)
}

/// Diff a fresh run against a recording under `policy`.
fn diff_run(fx: &Fixture, run: &RecordedRun, policy: &TolerancePolicy)
            -> FixtureResult {
    let mut failures = Vec::new();
    if run.responses.len() != fx.responses.len() {
        failures.push(format!(
            "response count {} != recorded {}",
            run.responses.len(), fx.responses.len()));
    }
    let n = run.responses.len().min(fx.responses.len());
    let mut frames_comparable = run.frames.len() == fx.frames.len();
    for i in 0..n {
        // ct-lint: allow(panic-index, reason = "i < n = min of both lengths by the loop bound")
        let (got, want) = (&run.responses[i], &fx.responses[i]);
        if got.len != want.len
            || got.span_start != want.span_start
            || got.session != want.session
        {
            failures.push(format!(
                "response {i}: identity mismatch — got len {} span {} \
                 session {:?}, recorded len {} span {} session {:?}",
                got.len, got.span_start, got.session,
                want.len, want.span_start, want.session));
        }
        if policy.require_bucket_match && got.bucket_n != want.bucket_n {
            failures.push(format!(
                "response {i}: served by bucket {} instead of recorded \
                 bucket {}", got.bucket_n, want.bucket_n));
        }
        if policy.require_cache_hit_match
            && got.cache_hit != want.cache_hit
        {
            failures.push(format!(
                "response {i}: cache_hit {:?} != recorded {:?}",
                got.cache_hit, want.cache_hit));
        }
        if got.elems != want.elems {
            frames_comparable = false;
            failures.push(format!(
                "response {i}: {} output elems != recorded {} — frame \
                 streams are misaligned, skipping the bit diff",
                got.elems, want.elems));
        }
    }
    let mut mismatched = 0usize;
    let mut first_diff = None;
    if frames_comparable {
        for (j, (g, w)) in
            run.frames.iter().zip(&fx.frames).enumerate()
        {
            if g.to_bits() != w.to_bits() {
                mismatched += 1;
                if first_diff.is_none() {
                    let (ri, ei) = locate(fx, j);
                    first_diff = Some(FrameDiff {
                        response: ri,
                        elem: ei,
                        got_bits: g.to_bits(),
                        want_bits: w.to_bits(),
                    });
                }
            }
        }
        if mismatched > 0 {
            failures.push(format!(
                "{mismatched} frame element(s) differ — outputs must \
                 be bit-exact"));
        }
    }
    if policy.require_counter_match && run.metrics != fx.metrics {
        failures.push(format!(
            "metric counters drifted — got {:?}, recorded {:?}",
            run.metrics, fx.metrics));
    }
    FixtureResult {
        name: fx.spec.name.clone(),
        passed: failures.is_empty(),
        checked_responses: n,
        mismatched_elems: mismatched,
        first_diff,
        failures,
        notes: Vec::new(),
    }
}

/// Re-run a fixture's spec on the current build and diff.  `perturb`
/// flips the low bit of the first fresh frame element before diffing —
/// the CI self-test that proves a changed bit actually turns the
/// report red.
pub fn replay_fixture(fx: &Fixture, policy: &TolerancePolicy,
                      perturb: bool) -> FixtureResult {
    match run_spec(&fx.spec, REPLAY_LANES) {
        Err(e) => FixtureResult::errored(&fx.spec.name, &e),
        Ok(mut run) => {
            let mut notes = Vec::new();
            if perturb {
                if let Some(x) = run.frames.first_mut() {
                    *x = f32::from_bits(x.to_bits() ^ 1);
                    notes.push("injected perturbation: flipped the low \
                                bit of frame element 0"
                        .to_string());
                }
            }
            let mut res = diff_run(fx, &run, policy);
            res.notes.extend(notes);
            res
        }
    }
}

/// Replay every named fixture in `dir`; `perturb` poisons the first
/// one.  Load errors become failing results, never panics — CI wants a
/// red report, not a stack trace.
pub fn replay_suite(dir: &std::path::Path, names: &[String],
                    policy: &TolerancePolicy, perturb: bool)
                    -> OracleReport {
    let mut fixtures = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let res = match Fixture::load(dir, name) {
            Err(e) => FixtureResult::errored(name, &e),
            Ok(fx) => replay_fixture(&fx, policy, perturb && i == 0),
        };
        fixtures.push(res);
    }
    OracleReport { fixtures, perf: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec(name: &str) -> FixtureSpec {
        FixtureSpec {
            name: name.into(),
            kernel: "full".into(),
            heads: 2,
            dk: 4,
            dv: 4,
            buckets: vec![8, 16],
            seed: 41,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::Mixed {
                min_len: 2, max_len: 12, count: 6,
                prefill: 4, steps: 2, step_len: 1, sessions: 2,
            },
        }
    }

    #[test]
    fn record_then_replay_is_bit_exact_across_lane_counts() {
        let fx = record_spec(&small_spec("unit-mixed")).unwrap();
        assert!(!fx.frames.is_empty());
        // decode sessions present and pinned
        assert!(fx.responses.iter().any(|r| r.session.is_some()));
        assert!(fx.responses.iter().any(|r| r.cache_hit == Some(true)));
        let res =
            replay_fixture(&fx, &TolerancePolicy::default(), false);
        assert!(res.passed, "failures: {:?}", res.failures);
        assert_eq!(res.checked_responses, fx.responses.len());
        assert_eq!(res.mismatched_elems, 0);
    }

    #[test]
    fn causal_linear_fixture_records_recurrent_hits_and_replays() {
        let spec = FixtureSpec {
            kernel: "linear".into(),
            causal: true,
            trace: TraceSpec::Decode {
                prefill: 4, steps: 2, step_len: 1, sessions: 2,
            },
            ..small_spec("unit-causal")
        };
        let fx = record_spec(&spec).unwrap();
        // the decode steps hit the recurrent-state cache entries
        assert!(fx.responses.iter().any(|r| r.cache_hit == Some(true)));
        assert!(fx.metrics.cache_hits >= 4);
        let res =
            replay_fixture(&fx, &TolerancePolicy::default(), false);
        assert!(res.passed, "failures: {:?}", res.failures);
        assert_eq!(res.mismatched_elems, 0);
    }

    #[test]
    fn perturbation_turns_the_diff_red_with_the_exact_bit() {
        let fx = record_spec(&small_spec("unit-perturb")).unwrap();
        let res = replay_fixture(&fx, &TolerancePolicy::default(), true);
        assert!(!res.passed);
        assert_eq!(res.mismatched_elems, 1);
        let diff = res.first_diff.expect("diff located");
        assert_eq!((diff.response, diff.elem), (0, 0));
        assert_eq!(diff.got_bits ^ diff.want_bits, 1);
        assert!(res.notes.iter().any(|n| n.contains("perturbation")));
    }

    #[test]
    fn identity_fixture_matches_the_closed_form() {
        let specs = standard_suite();
        let identity = specs.iter()
            .find(|s| s.name == "identity-len1")
            .unwrap();
        let fx = record_spec(identity).unwrap();
        let expected = identity_expected_frames(
            identity.shape(),
            match identity.trace {
                TraceSpec::IdentityLen1 { count } => count,
                _ => unreachable!(),
            });
        assert_eq!(fx.frames.len(), expected.len());
        for (g, w) in fx.frames.iter().zip(&expected) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // all six land in the only bucket, no sessions, no cache
        assert_eq!(fx.metrics.completed, vec![6]);
        assert_eq!(fx.metrics.cache_hits, 0);
        assert_eq!(fx.metrics.cache_misses, 0);
    }

    #[test]
    fn suite_round_trips_through_disk() {
        let dir = std::env::temp_dir()
            .join(format!("ct-oracle-suite-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let specs = vec![small_spec("unit-disk")];
        let recorded = record_suite(&dir, &specs, false).unwrap();
        assert_eq!(recorded, vec!["unit-disk"]);
        // second record without force is a no-op
        assert!(record_suite(&dir, &specs, false).unwrap().is_empty());
        let names = Manifest::load(&dir).unwrap().fixtures;
        assert_eq!(names, vec!["unit-disk"]);
        let report = replay_suite(&dir, &names,
                                  &TolerancePolicy::default(), false);
        assert!(report.passed(),
                "failures: {:?}", report.fixtures[0].failures);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
