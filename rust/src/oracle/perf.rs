//! ct-contract: panic-free
//!
//! Perf-regression gate: fresh `BENCH_*.json` files vs checked-in
//! baselines.
//!
//! The bench harness (`cargo bench`, or `CT_SMOKE=1` in CI) drops one
//! `BENCH_<name>.json` per suite at the repo root.  This gate compares
//! each of them against `bench-baselines/BENCH_<name>.json` and fails
//! when any row's `rows_per_sec` falls below
//! `baseline · (1 − max_bench_regression)` (policy default: 15%).
//!
//! Matching is by row name.  The failure modes are asymmetric on
//! purpose:
//!
//! - A baseline row **missing from the fresh run** fails the gate —
//!   silently losing bench coverage is exactly the regression class a
//!   gate exists to catch.
//! - A fresh row with no baseline passes with a note — new benches
//!   must not need a baseline to land, they get one at the next bless.
//! - A baseline *file* with no fresh counterpart is a warn-pass note —
//!   CI shards may run bench suites selectively.
//! - A baseline file carrying `"bootstrap": true` is skipped: it marks
//!   a placeholder checked in before any real numbers existed (this
//!   repo's builds happen on the CI host, so first-run baselines are
//!   recorded there and blessed in a follow-up).  `ct oracle bless
//!   --bench` rewrites baselines from fresh files without the flag.
//!
//! Latency percentiles are reported but never gated — `rows_per_sec`
//! over a fixed workload is the one number that is comparable across
//! runs on the same host class.
//!
//! `self_check()` proves the red path end to end on every CI run: it
//! fabricates a baseline and a 25%-slower fresh copy in a temp dir and
//! asserts the gate fails, so a broken gate cannot silently pass real
//! regressions.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::benchlib::{parse_bench_doc, BenchRecord};
use crate::jsonio::{self, obj, Value};

/// Verdict for one baseline/fresh row pair.
#[derive(Debug, Clone, PartialEq)]
pub enum RowStatus {
    /// Within tolerance (ratio ≥ 1 − max_regression).
    Pass,
    /// Regressed beyond tolerance, or lost from the fresh run.
    Fail,
    /// Fresh row with no baseline — passes, blessed later.
    New,
}

#[derive(Debug, Clone)]
pub struct RowGate {
    pub name: String,
    pub baseline_rps: f64,
    pub fresh_rps: f64,
    pub status: RowStatus,
}

impl RowGate {
    fn to_value(&self) -> Value {
        let ratio = if self.baseline_rps > 0.0 {
            self.fresh_rps / self.baseline_rps
        } else {
            0.0
        };
        obj(vec![
            ("name", self.name.as_str().into()),
            ("status", match self.status {
                RowStatus::Pass => "pass",
                RowStatus::Fail => "fail",
                RowStatus::New => "new",
            }.into()),
            ("baseline_rows_per_sec", self.baseline_rps.into()),
            ("fresh_rows_per_sec", self.fresh_rps.into()),
            ("ratio", ratio.into()),
        ])
    }
}

/// Verdict for one `BENCH_*.json` file.
#[derive(Debug, Clone)]
pub struct BenchGate {
    /// File name, e.g. `BENCH_gateway.json`.
    pub file: String,
    /// `"pass"`, `"fail"`, or one of the `"skipped-*"` warn-pass
    /// states (see module docs).
    pub status: String,
    pub rows: Vec<RowGate>,
    pub notes: Vec<String>,
}

impl BenchGate {
    fn skipped(file: &str, status: &str, note: String) -> Self {
        Self { file: file.to_string(), status: status.to_string(),
               rows: Vec::new(), notes: vec![note] }
    }

    pub fn failed(&self) -> bool {
        self.status == "fail"
    }

    fn to_value(&self) -> Value {
        obj(vec![
            ("file", self.file.as_str().into()),
            ("status", self.status.as_str().into()),
            ("rows", Value::Arr(
                self.rows.iter().map(RowGate::to_value).collect())),
            ("notes", Value::Arr(
                self.notes.iter().map(|s| s.as_str().into())
                    .collect())),
        ])
    }
}

/// The whole gate run, mergeable into `oracle-report.json`.
#[derive(Debug, Clone)]
pub struct PerfGateResult {
    pub max_regression: f64,
    pub benches: Vec<BenchGate>,
}

impl PerfGateResult {
    pub fn passed(&self) -> bool {
        !self.benches.iter().any(BenchGate::failed)
    }

    /// Suites whose baseline is still a bootstrap placeholder: the
    /// gate compared nothing for them.  `ct oracle perf-gate` prints
    /// one loud `SKIPPED (bootstrap baseline)` line per entry, and its
    /// `--strict` mode turns a non-empty list into a failure so CI can
    /// flag baselines that were never blessed.
    pub fn bootstrap_skips(&self) -> Vec<&str> {
        self.benches
            .iter()
            .filter(|b| b.status == "skipped-bootstrap")
            .map(|b| b.file.as_str())
            .collect()
    }

    pub fn to_value(&self) -> Value {
        obj(vec![
            ("status",
             if self.passed() { "pass" } else { "fail" }.into()),
            ("max_regression", self.max_regression.into()),
            ("benches", Value::Arr(
                self.benches.iter().map(BenchGate::to_value)
                    .collect())),
        ])
    }
}

/// Row-by-row comparison of one bench suite.  Baseline rows with
/// non-positive `rows_per_sec` are skipped (a zeroed row carries no
/// signal).
pub fn compare_records(baseline: &[BenchRecord], fresh: &[BenchRecord],
                       max_regression: f64) -> Vec<RowGate> {
    let mut rows = Vec::new();
    for b in baseline {
        if b.rows_per_sec <= 0.0 {
            continue;
        }
        match fresh.iter().find(|f| f.name == b.name) {
            None => rows.push(RowGate {
                name: b.name.clone(),
                baseline_rps: b.rows_per_sec,
                fresh_rps: 0.0,
                status: RowStatus::Fail,
            }),
            Some(f) => {
                let floor = b.rows_per_sec * (1.0 - max_regression);
                rows.push(RowGate {
                    name: b.name.clone(),
                    baseline_rps: b.rows_per_sec,
                    fresh_rps: f.rows_per_sec,
                    status: if f.rows_per_sec >= floor {
                        RowStatus::Pass
                    } else {
                        RowStatus::Fail
                    },
                });
            }
        }
    }
    for f in fresh {
        if !baseline.iter().any(|b| b.name == f.name) {
            rows.push(RowGate {
                name: f.name.clone(),
                baseline_rps: 0.0,
                fresh_rps: f.rows_per_sec,
                status: RowStatus::New,
            });
        }
    }
    rows
}

fn gate_one(file: &str, baseline_doc: &Value, fresh_doc: &Value,
            max_regression: f64) -> Result<BenchGate> {
    if baseline_doc.get("bootstrap").as_bool() == Some(true) {
        return Ok(BenchGate::skipped(
            file, "skipped-bootstrap",
            "baseline is a bootstrap placeholder — run `ct oracle \
             bless --bench` on a healthy build to pin real numbers"
                .into()));
    }
    let (_, baseline) = parse_bench_doc(baseline_doc)?;
    let (_, fresh) = parse_bench_doc(fresh_doc)?;
    let rows = compare_records(&baseline, &fresh, max_regression);
    let mut notes = Vec::new();
    for r in &rows {
        match r.status {
            RowStatus::Fail if r.fresh_rps == 0.0 => notes.push(format!(
                "row {:?} present in baseline but missing from the \
                 fresh run (lost bench coverage)", r.name)),
            RowStatus::Fail => notes.push(format!(
                "row {:?} regressed: {:.1} → {:.1} rows/s ({:.1}% \
                 below baseline, tolerance {:.0}%)",
                r.name, r.baseline_rps, r.fresh_rps,
                (1.0 - r.fresh_rps / r.baseline_rps) * 100.0,
                max_regression * 100.0)),
            RowStatus::New => notes.push(format!(
                "row {:?} is new (no baseline yet)", r.name)),
            RowStatus::Pass => {}
        }
    }
    let failed = rows.iter().any(|r| r.status == RowStatus::Fail);
    Ok(BenchGate {
        file: file.to_string(),
        status: if failed { "fail" } else { "pass" }.to_string(),
        rows,
        notes,
    })
}

/// Run the gate: every `BENCH_*.json` directly under `fresh_dir`
/// against its same-named file under `baseline_dir`.  Never errors on
/// missing files (those are warn-pass states); errors only on
/// unreadable/unparseable JSON.
pub fn run_perf_gate(fresh_dir: &Path, baseline_dir: &Path,
                     max_regression: f64) -> Result<PerfGateResult> {
    let list = |dir: &Path| -> Result<Vec<String>> {
        let mut names = Vec::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(dir)? {
                let name = entry?.file_name();
                let name = name.to_string_lossy().to_string();
                if name.starts_with("BENCH_") && name.ends_with(".json")
                {
                    names.push(name);
                }
            }
        }
        names.sort();
        Ok(names)
    };
    let read = |path: &Path| -> Result<Value> {
        jsonio::parse(&std::fs::read_to_string(path)
                .map_err(|e| anyhow!("read {}: {e}", path.display()))?)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))
    };
    let fresh_files = list(fresh_dir)?;
    let baseline_files = list(baseline_dir)?;
    let mut benches = Vec::new();
    for file in &fresh_files {
        let bp = baseline_dir.join(file);
        if !bp.exists() {
            benches.push(BenchGate::skipped(
                file, "skipped-no-baseline",
                format!("no baseline {} — gate passes; bless one when \
                         the numbers are trusted", bp.display())));
            continue;
        }
        benches.push(gate_one(file, &read(&bp)?,
                              &read(&fresh_dir.join(file))?,
                              max_regression)?);
    }
    for file in &baseline_files {
        if !fresh_files.contains(file) {
            benches.push(BenchGate::skipped(
                file, "skipped-no-fresh",
                "baseline exists but this run produced no fresh file \
                 (bench suite not run here)".into()));
        }
    }
    Ok(PerfGateResult { max_regression, benches })
}

/// Build a minimal bench document in the `write_bench_json` schema —
/// used by `self_check` and tests to fabricate suites without timing
/// anything.
pub fn bench_doc(bench: &str, rows: &[(&str, f64)]) -> Value {
    obj(vec![
        ("bench", bench.into()),
        ("peak_rss_bytes", 0.0.into()),
        ("records", Value::Arr(rows.iter().map(|&(name, rps)| obj(vec![
            ("name", name.into()),
            ("rows_per_sec", rps.into()),
            ("mean_us", 1.0.into()),
            ("p50_us", 1.0.into()),
            ("p99_us", 2.0.into()),
            ("iters", 10usize.into()),
        ])).collect())),
    ])
}

/// Prove the gate's red path: fabricate a baseline and a fresh run
/// regressed past tolerance, assert the gate fails, then assert an
/// identical fresh run passes.  Errors if either direction misbehaves —
/// CI runs this before trusting a green gate.
pub fn self_check(max_regression: f64) -> Result<()> {
    let root = std::env::temp_dir().join(format!(
        "ct-oracle-perf-selfcheck-{}", std::process::id()));
    let fresh_dir = root.join("fresh");
    let base_dir = root.join("baselines");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&fresh_dir)?;
    std::fs::create_dir_all(&base_dir)?;
    let write = |dir: &Path, rows: &[(&str, f64)]| -> Result<()> {
        std::fs::write(dir.join("BENCH_selfcheck.json"),
                       jsonio::to_string_pretty(
                           &bench_doc("selfcheck", rows)))?;
        Ok(())
    };
    let baseline = [("alpha", 1000.0), ("beta", 2000.0)];
    write(&base_dir, &baseline)?;
    // regress beta past the tolerance band
    let slow = [("alpha", 1000.0),
                ("beta", 2000.0 * (1.0 - max_regression) * 0.9)];
    write(&fresh_dir, &slow)?;
    let gate = run_perf_gate(&fresh_dir, &base_dir, max_regression)?;
    if gate.passed() {
        bail!("perf-gate self-check: a regression past the {:.0}% \
               tolerance passed — the gate is broken",
              max_regression * 100.0);
    }
    write(&fresh_dir, &baseline)?;
    let gate = run_perf_gate(&fresh_dir, &base_dir, max_regression)?;
    if !gate.passed() {
        bail!("perf-gate self-check: identical numbers failed the gate");
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, rps: f64) -> BenchRecord {
        BenchRecord {
            name: name.into(),
            rows_per_sec: rps,
            mean_us: 1.0,
            p50_us: 1.0,
            p99_us: 2.0,
            iters: 10,
            extra: Vec::new(),
        }
    }

    #[test]
    fn rows_within_band_pass_and_regressions_fail() {
        let baseline = [rec("a", 1000.0), rec("b", 500.0)];
        // a: −10% (inside 15% band), b: −20% (outside)
        let fresh = [rec("a", 900.0), rec("b", 400.0)];
        let rows = compare_records(&baseline, &fresh, 0.15);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].status, RowStatus::Pass);
        assert_eq!(rows[1].status, RowStatus::Fail);
    }

    #[test]
    fn lost_rows_fail_and_new_rows_pass() {
        let baseline = [rec("kept", 100.0), rec("lost", 100.0),
                        rec("zeroed", 0.0)];
        let fresh = [rec("kept", 100.0), rec("brand-new", 5.0)];
        let rows = compare_records(&baseline, &fresh, 0.15);
        let by_name = |n: &str| {
            rows.iter().find(|r| r.name == n).unwrap().status.clone()
        };
        assert_eq!(by_name("kept"), RowStatus::Pass);
        assert_eq!(by_name("lost"), RowStatus::Fail);
        assert_eq!(by_name("brand-new"), RowStatus::New);
        // zero-rps baseline rows carry no signal and are dropped
        assert!(!rows.iter().any(|r| r.name == "zeroed"));
    }

    #[test]
    fn faster_is_always_fine() {
        let rows = compare_records(&[rec("a", 100.0)],
                                   &[rec("a", 10_000.0)], 0.15);
        assert_eq!(rows[0].status, RowStatus::Pass);
    }

    #[test]
    fn bootstrap_baselines_are_skipped_not_gated() {
        let mut doc = bench_doc("x", &[("a", 1.0)]);
        doc.set("bootstrap", true.into());
        let fresh = bench_doc("x", &[("a", 0.001)]);
        let gate = gate_one("BENCH_x.json", &doc, &fresh, 0.15).unwrap();
        assert_eq!(gate.status, "skipped-bootstrap");
        assert!(!gate.failed());
        // ...but never silently: the skip is enumerable for the CLI's
        // loud per-suite line and the --strict failure mode
        let result = PerfGateResult { max_regression: 0.15,
                                      benches: vec![gate] };
        assert!(result.passed());
        assert_eq!(result.bootstrap_skips(), vec!["BENCH_x.json"]);
        // other skip flavors are not bootstrap skips
        let other = PerfGateResult {
            max_regression: 0.15,
            benches: vec![BenchGate::skipped(
                "BENCH_y.json", "skipped-no-fresh", "n/a".into())],
        };
        assert!(other.bootstrap_skips().is_empty());
    }

    #[test]
    fn self_check_proves_the_red_path() {
        self_check(0.15).unwrap();
    }

    #[test]
    fn gate_over_directories_handles_all_skip_states() {
        let root = std::env::temp_dir().join(format!(
            "ct-oracle-perf-dirs-{}", std::process::id()));
        let fresh = root.join("fresh");
        let base = root.join("base");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&fresh).unwrap();
        std::fs::create_dir_all(&base).unwrap();
        // fresh-only file → skipped-no-baseline
        std::fs::write(fresh.join("BENCH_new.json"),
                       jsonio::to_string(
                           &bench_doc("new", &[("r", 1.0)]))).unwrap();
        // baseline-only file → skipped-no-fresh
        std::fs::write(base.join("BENCH_old.json"),
                       jsonio::to_string(
                           &bench_doc("old", &[("r", 1.0)]))).unwrap();
        // non-bench files are ignored
        std::fs::write(fresh.join("notes.txt"), "x").unwrap();
        let gate = run_perf_gate(&fresh, &base, 0.15).unwrap();
        assert!(gate.passed());
        let statuses: Vec<&str> =
            gate.benches.iter().map(|b| b.status.as_str()).collect();
        assert_eq!(statuses,
                   vec!["skipped-no-baseline", "skipped-no-fresh"]);
        // serialized verdict is stable and carries the verdict
        let v = gate.to_value();
        assert_eq!(v.get("status").as_str(), Some("pass"));
        let _ = std::fs::remove_dir_all(&root);
    }
}
