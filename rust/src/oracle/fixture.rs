//! ct-contract: panic-free
//!
//! Golden-trace fixture model and on-disk format.
//!
//! A fixture is a *regenerable* recording of the serving gateway over a
//! seeded trace: the spec (kernel, shape, buckets, seed, trace
//! parameters) fully determines the requests, so the fixture files only
//! need to store what the gateway **returned** — per-response metadata
//! plus the output frames — and the expected metric counters.
//!
//! On disk a fixture `<name>` is two files in the fixture directory:
//!
//! - `<name>.json` — pretty-printed header: format version, the spec,
//!   one metadata record per response (lengths, spans, sessions,
//!   cache-hit flags, serving bucket, frame element count), the
//!   expected metric counters, and the frame file's element count +
//!   FNV-1a-64 checksum.
//! - `<name>.bin` — the response output frames, concatenated in trace
//!   order as raw little-endian f32 (the shard wire-frame codec,
//!   `attention::sharded::write_f32s`).
//!
//! `manifest.json` lists the fixture names (sorted — the file is
//! byte-stable) so `ct oracle replay` knows the full suite without
//! globbing.
//!
//! u64 values that must survive JSON exactly (seeds, session ids, the
//! checksum) travel as 16-hex-digit strings, same as the shard wire
//! protocol — JSON `f64` rounds past 2^53.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::attention::sharded::{hex_u64, parse_hex_u64, write_f32s};
use crate::coordinator::{synthetic_decode_trace, synthetic_trace,
                         GatewayResponse, GatewayShape, ServingGateway,
                         TraceItem};
use crate::jsonio::{self, obj, Value};

/// Version stamp of the fixture on-disk format.  Bump on any breaking
/// header/frame layout change; `load` rejects mismatches with a
/// re-record hint instead of mis-diffing.
pub const FORMAT_VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// trace specs
// ---------------------------------------------------------------------------

/// The seeded trace a fixture drives through the gateway.  Generation is
/// a pure function of `(spec, shape, seed)`, which is what makes
/// fixtures regenerable from their header alone.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSpec {
    /// Ragged one-shot requests, log₂-uniform lengths
    /// ([`synthetic_trace`]).
    Ragged { min_len: usize, max_len: usize, count: usize },
    /// Multi-step decode sessions ([`synthetic_decode_trace`]).
    Decode { prefill: usize, steps: usize, step_len: usize,
             sessions: usize },
    /// Ragged one-shots interleaved item-by-item with decode-session
    /// steps (the decode half draws from `seed + 1` so the two streams
    /// stay independent).
    Mixed { min_len: usize, max_len: usize, count: usize,
            prefill: usize, steps: usize, step_len: usize,
            sessions: usize },
    /// `count` single-row full-attention requests with closed-form
    /// pattern tensors: softmax over one element is exactly 1.0, so the
    /// expected output **is the V block, bit for bit**
    /// ([`identity_expected_frames`]).  The one fixture whose `.bin`
    /// can be authored by hand and checked in.
    IdentityLen1 { count: usize },
}

/// The deterministic tensor fill of the identity trace: element `j` of
/// tensor `c` (0 = q, 1 = k, 2 = v) of request `r`.  Every value is an
/// integer in [0, 250] times 2⁻⁶ — exactly representable in f32, so any
/// independent implementation of this formula reproduces the bytes.
pub fn pattern_value(c: usize, r: usize, j: usize) -> f32 {
    ((r * 31 + j * 7 + c * 13) % 251) as f32 * 0.015625
}

/// The expected `.bin` frame stream of an `IdentityLen1 { count }`
/// fixture: each response is its request's V block exactly (single-row
/// softmax weight is exactly 1.0 and `1.0 * v` is exact in f32).
pub fn identity_expected_frames(shape: GatewayShape, count: usize)
                                -> Vec<f32> {
    let mut frames = Vec::with_capacity(count * shape.v_len(1));
    for r in 0..count {
        frames.extend((0..shape.v_len(1)).map(|j| pattern_value(2, r, j)));
    }
    frames
}

impl TraceSpec {
    /// Generate the trace this spec describes (pure in `(self, shape,
    /// seed)`).
    pub fn generate(&self, shape: GatewayShape, seed: u64)
                    -> Vec<TraceItem> {
        match *self {
            TraceSpec::Ragged { min_len, max_len, count } => {
                synthetic_trace(shape, min_len, max_len, count, seed)
            }
            TraceSpec::Decode { prefill, steps, step_len, sessions } => {
                synthetic_decode_trace(shape, prefill, steps, step_len,
                                       sessions, seed)
            }
            TraceSpec::Mixed { min_len, max_len, count, prefill, steps,
                               step_len, sessions } => {
                let shots =
                    synthetic_trace(shape, min_len, max_len, count, seed);
                let decode = synthetic_decode_trace(
                    shape, prefill, steps, step_len, sessions,
                    // ct-lint: allow(det-seed-arith, reason = "recorded fixture seed derivation: changing it invalidates every checked-in golden fixture")
                    seed.wrapping_add(1));
                interleave(shots, decode)
            }
            TraceSpec::IdentityLen1 { count } => (0..count)
                .map(|r| TraceItem {
                    q: (0..shape.qk_len(1))
                        .map(|j| pattern_value(0, r, j))
                        .collect(),
                    k: (0..shape.qk_len(1))
                        .map(|j| pattern_value(1, r, j))
                        .collect(),
                    v: (0..shape.v_len(1))
                        .map(|j| pattern_value(2, r, j))
                        .collect(),
                    len: 1,
                    session: None,
                })
                .collect(),
        }
    }

    pub fn to_value(&self) -> Value {
        match *self {
            TraceSpec::Ragged { min_len, max_len, count } => obj(vec![
                ("kind", "ragged".into()),
                ("min_len", min_len.into()),
                ("max_len", max_len.into()),
                ("count", count.into()),
            ]),
            TraceSpec::Decode { prefill, steps, step_len, sessions } => {
                obj(vec![
                    ("kind", "decode".into()),
                    ("prefill", prefill.into()),
                    ("steps", steps.into()),
                    ("step_len", step_len.into()),
                    ("sessions", sessions.into()),
                ])
            }
            TraceSpec::Mixed { min_len, max_len, count, prefill, steps,
                               step_len, sessions } => obj(vec![
                ("kind", "mixed".into()),
                ("min_len", min_len.into()),
                ("max_len", max_len.into()),
                ("count", count.into()),
                ("prefill", prefill.into()),
                ("steps", steps.into()),
                ("step_len", step_len.into()),
                ("sessions", sessions.into()),
            ]),
            TraceSpec::IdentityLen1 { count } => obj(vec![
                ("kind", "identity-len1".into()),
                ("count", count.into()),
            ]),
        }
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let field = |key: &str| {
            v.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("trace spec: missing {key:?}"))
        };
        match v.get("kind").as_str() {
            Some("ragged") => Ok(TraceSpec::Ragged {
                min_len: field("min_len")?,
                max_len: field("max_len")?,
                count: field("count")?,
            }),
            Some("decode") => Ok(TraceSpec::Decode {
                prefill: field("prefill")?,
                steps: field("steps")?,
                step_len: field("step_len")?,
                sessions: field("sessions")?,
            }),
            Some("mixed") => Ok(TraceSpec::Mixed {
                min_len: field("min_len")?,
                max_len: field("max_len")?,
                count: field("count")?,
                prefill: field("prefill")?,
                steps: field("steps")?,
                step_len: field("step_len")?,
                sessions: field("sessions")?,
            }),
            Some("identity-len1") => Ok(TraceSpec::IdentityLen1 {
                count: field("count")?,
            }),
            other => bail!("trace spec: unknown kind {other:?}"),
        }
    }
}

/// Alternate `a[0], b[0], a[1], b[1], …` preserving each stream's
/// internal order (decode steps must stay in session order).
fn interleave(a: Vec<TraceItem>, b: Vec<TraceItem>) -> Vec<TraceItem> {
    let mut a = a.into_iter();
    let mut b = b.into_iter();
    let mut out = Vec::with_capacity(a.len() + b.len());
    loop {
        let (x, y) = (a.next(), b.next());
        if x.is_none() && y.is_none() {
            return out;
        }
        out.extend(x);
        out.extend(y);
    }
}

// ---------------------------------------------------------------------------
// fixture spec
// ---------------------------------------------------------------------------

/// Everything needed to regenerate a fixture's requests and rebuild the
/// gateway that serves them.
///
/// **Bucket batch size is pinned to 1.**  One-shot PRNG streams key off
/// the batch *slot* (`slice_stream(seed, slot·H + h)`), so a
/// multi-request flush's bits depend on which requests happened to
/// co-batch — timing, not data.  Single-request flushes make every
/// response a pure function of its own item, which is the composition
/// independence the record/replay parity diff (and the lane-invariance
/// property test) stands on.  Session streams are slot-independent by
/// design (`prng::session_seed`) but ride the same rule.
#[derive(Debug, Clone, PartialEq)]
pub struct FixtureSpec {
    /// Fixture (and file-stem) name: `[a-z0-9-]+`.
    pub name: String,
    /// Attention-registry kernel every bucket runs.
    pub kernel: String,
    pub heads: usize,
    pub dk: usize,
    pub dv: usize,
    /// Bucket pad-to lengths, ascending (each `Bucket::native(kernel,
    /// n, 1)`).
    pub buckets: Vec<usize>,
    /// Gateway + trace seed.
    pub seed: u64,
    /// Valid-length masking (`GatewayOptions::mask`).
    pub masked: bool,
    /// Autoregressive serving (`GatewayOptions::causal`): needs a
    /// causal-capable kernel (the linear family); decode sessions then
    /// pin the O(1) recurrent-state cache path.  Emitted in the header
    /// only when true and parsed leniently, so pre-causal fixture
    /// files load unchanged.
    pub causal: bool,
    /// 0 = single-host native serving; N = fan out over N local
    /// `ct shard-worker` instances spawned for the run (the multi-host
    /// path, exercised hermetically).
    pub shards: usize,
    pub trace: TraceSpec,
}

impl FixtureSpec {
    pub fn shape(&self) -> GatewayShape {
        GatewayShape { heads: self.heads, dk: self.dk, dv: self.dv }
    }

    fn validate(&self) -> Result<()> {
        if self.name.is_empty()
            || !self.name.chars().all(|c| {
                c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'
            })
        {
            bail!("fixture name {:?} must be non-empty [a-z0-9-]+ (it \
                   names files)", self.name);
        }
        if self.buckets.is_empty() {
            bail!("fixture {:?} has no buckets", self.name);
        }
        Ok(())
    }

    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("name", self.name.as_str().into()),
            ("kernel", self.kernel.as_str().into()),
            ("heads", self.heads.into()),
            ("dk", self.dk.into()),
            ("dv", self.dv.into()),
            ("buckets", Value::Arr(
                self.buckets.iter().map(|&n| n.into()).collect())),
            ("seed", hex_u64(self.seed).into()),
            ("masked", self.masked.into()),
        ];
        // emitted only when true: pre-causal headers stay byte-stable
        if self.causal {
            fields.push(("causal", true.into()));
        }
        fields.push(("shards", self.shards.into()));
        fields.push(("trace", self.trace.to_value()));
        obj(fields)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let field = |key: &str| {
            v.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("fixture spec: missing {key:?}"))
        };
        let spec = FixtureSpec {
            name: v.get("name")
                .as_str()
                .ok_or_else(|| anyhow!("fixture spec: missing name"))?
                .to_string(),
            kernel: v.get("kernel")
                .as_str()
                .ok_or_else(|| anyhow!("fixture spec: missing kernel"))?
                .to_string(),
            heads: field("heads")?,
            dk: field("dk")?,
            dv: field("dv")?,
            buckets: v.get("buckets")
                .as_arr()
                .ok_or_else(|| anyhow!("fixture spec: missing buckets"))?
                .iter()
                .map(|b| b.as_usize()
                    .ok_or_else(|| anyhow!("fixture spec: bad bucket")))
                .collect::<Result<_>>()?,
            seed: parse_hex_u64(v.get("seed"))?,
            masked: v.get("masked")
                .as_bool()
                .ok_or_else(|| anyhow!("fixture spec: missing masked"))?,
            // lenient: absent in pre-causal headers means false
            causal: v.get("causal").as_bool().unwrap_or(false),
            shards: field("shards")?,
            trace: TraceSpec::from_value(v.get("trace"))?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

// ---------------------------------------------------------------------------
// recorded responses + metrics
// ---------------------------------------------------------------------------

/// Per-response metadata the replay diff checks alongside the frame
/// bytes.  Everything here is deterministic under the batch-size-1
/// serving discipline (see [`FixtureSpec`]); latencies are *not*
/// recorded — they are machine noise, and the perf gate owns timing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RespMeta {
    pub len: usize,
    pub span_start: usize,
    pub session: Option<u64>,
    pub cache_hit: Option<bool>,
    /// Pad-to length of the serving bucket.
    pub bucket_n: usize,
    /// f32 elements this response contributed to the frame stream.
    pub elems: usize,
}

impl RespMeta {
    pub fn from_response(r: &GatewayResponse) -> Self {
        Self {
            len: r.len,
            span_start: r.span_start,
            session: r.session,
            cache_hit: r.cache_hit,
            bucket_n: r.bucket_seq_len,
            elems: r.out.len(),
        }
    }

    pub fn to_value(&self) -> Value {
        obj(vec![
            ("len", self.len.into()),
            ("span_start", self.span_start.into()),
            ("session", match self.session {
                Some(sid) => hex_u64(sid).into(),
                None => Value::Null,
            }),
            ("cache_hit", match self.cache_hit {
                Some(b) => b.into(),
                None => Value::Null,
            }),
            ("bucket_n", self.bucket_n.into()),
            ("elems", self.elems.into()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let field = |key: &str| {
            v.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("response meta: missing {key:?}"))
        };
        Ok(Self {
            len: field("len")?,
            span_start: field("span_start")?,
            session: match v.get("session") {
                Value::Null => None,
                s => Some(parse_hex_u64(s)?),
            },
            cache_hit: match v.get("cache_hit") {
                Value::Null => None,
                b => Some(b.as_bool().ok_or_else(
                    || anyhow!("response meta: bad cache_hit"))?),
            },
            bucket_n: field("bucket_n")?,
            elems: field("elems")?,
        })
    }
}

/// The deterministic gateway counters a fixture pins: per-bucket
/// completed counts plus the gateway-wide cache/session totals.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    /// Completed requests per bucket, ascending seq_len order.
    pub completed: Vec<u64>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub saved_rows: u64,
    pub recomputed_rows: u64,
    pub session_route_up: u64,
}

impl MetricsSnapshot {
    pub fn capture(gw: &ServingGateway) -> Self {
        use std::sync::atomic::Ordering::Relaxed;
        let ms = gw.bucket_metrics();
        Self {
            completed: ms.iter()
                .map(|m| m.completed.load(Relaxed))
                .collect(),
            cache_hits: ms.iter()
                .map(|m| m.cache_hits.load(Relaxed))
                .sum(),
            cache_misses: ms.iter()
                .map(|m| m.cache_misses.load(Relaxed))
                .sum(),
            saved_rows: ms.iter()
                .map(|m| m.saved_rows.load(Relaxed))
                .sum(),
            recomputed_rows: ms.iter()
                .map(|m| m.recomputed_rows.load(Relaxed))
                .sum(),
            session_route_up: ms.iter()
                .map(|m| m.session_route_up.load(Relaxed))
                .sum(),
        }
    }

    pub fn to_value(&self) -> Value {
        obj(vec![
            ("completed", Value::Arr(
                self.completed.iter().map(|&n| (n as usize).into())
                    .collect())),
            ("cache_hits", (self.cache_hits as usize).into()),
            ("cache_misses", (self.cache_misses as usize).into()),
            ("saved_rows", (self.saved_rows as usize).into()),
            ("recomputed_rows", (self.recomputed_rows as usize).into()),
            ("session_route_up",
             (self.session_route_up as usize).into()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let field = |key: &str| {
            v.get(key)
                .as_usize()
                .map(|n| n as u64)
                .ok_or_else(|| anyhow!("metrics: missing {key:?}"))
        };
        Ok(Self {
            completed: v.get("completed")
                .as_arr()
                .ok_or_else(|| anyhow!("metrics: missing completed"))?
                .iter()
                .map(|n| n.as_usize()
                    .map(|n| n as u64)
                    .ok_or_else(|| anyhow!("metrics: bad completed")))
                .collect::<Result<_>>()?,
            cache_hits: field("cache_hits")?,
            cache_misses: field("cache_misses")?,
            saved_rows: field("saved_rows")?,
            recomputed_rows: field("recomputed_rows")?,
            session_route_up: field("session_route_up")?,
        })
    }
}

// ---------------------------------------------------------------------------
// the fixture itself + file I/O
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit over a byte stream — the frame-file checksum.  Chosen
/// for being trivially reimplementable (the identity fixture's header
/// is authored outside this crate) and good enough to catch truncation
/// and bit rot; this is an integrity check, not a security boundary.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Frame stream → the raw little-endian bytes of the `.bin` file.
pub fn frames_to_bytes(frames: &[f32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(frames.len() * 4);
    // ct-lint: allow(panic-expect, reason = "io::Write to a Vec cannot fail; threading a Result through every fixture caller for an infallible write hides real errors")
    write_f32s(&mut buf, frames).expect("Vec write is infallible");
    buf
}

/// One recorded golden fixture: spec + expected responses, metrics and
/// output frames.
#[derive(Debug, Clone, PartialEq)]
pub struct Fixture {
    pub spec: FixtureSpec,
    pub responses: Vec<RespMeta>,
    pub metrics: MetricsSnapshot,
    /// All response outputs concatenated in trace order.
    pub frames: Vec<f32>,
}

impl Fixture {
    fn header_path(dir: &Path, name: &str) -> PathBuf {
        dir.join(format!("{name}.json"))
    }

    fn frames_path(dir: &Path, name: &str) -> PathBuf {
        dir.join(format!("{name}.bin"))
    }

    /// Whether both fixture files exist under `dir`.
    pub fn exists(dir: &Path, name: &str) -> bool {
        Self::header_path(dir, name).exists()
            && Self::frames_path(dir, name).exists()
    }

    pub fn to_value(&self) -> Value {
        let bytes = frames_to_bytes(&self.frames);
        obj(vec![
            ("format_version", (FORMAT_VERSION as usize).into()),
            ("spec", self.spec.to_value()),
            ("responses", Value::Arr(
                self.responses.iter().map(RespMeta::to_value).collect())),
            ("metrics", self.metrics.to_value()),
            ("frames", obj(vec![
                ("file", format!("{}.bin", self.spec.name).into()),
                ("total_elems", self.frames.len().into()),
                ("fnv1a64", hex_u64(fnv1a64(&bytes)).into()),
            ])),
        ])
    }

    /// Write `<name>.json` + `<name>.bin` under `dir` (created if
    /// missing).  The header is pretty-printed stable JSON — recording
    /// an unchanged build over an unchanged spec is byte-identical.
    pub fn save(&self, dir: &Path) -> Result<()> {
        self.spec.validate()?;
        let total: usize = self.responses.iter().map(|r| r.elems).sum();
        if total != self.frames.len() {
            bail!("fixture {:?}: responses claim {total} frame elems, \
                   stream has {}", self.spec.name, self.frames.len());
        }
        std::fs::create_dir_all(dir)?;
        std::fs::write(Self::header_path(dir, &self.spec.name),
                       jsonio::to_string_pretty(&self.to_value()))?;
        std::fs::write(Self::frames_path(dir, &self.spec.name),
                       frames_to_bytes(&self.frames))?;
        Ok(())
    }

    /// Load and integrity-check a fixture: format version, frame count,
    /// checksum, and per-response element accounting all verified here,
    /// so the replay diff only ever compares well-formed recordings.
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let hp = Self::header_path(dir, name);
        let text = std::fs::read_to_string(&hp)
            .map_err(|e| anyhow!("read {}: {e}", hp.display()))?;
        let v = jsonio::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", hp.display()))?;
        let version = v.get("format_version").as_usize().unwrap_or(0);
        if version != FORMAT_VERSION as usize {
            bail!("fixture {name:?} is format v{version}, this build \
                   reads v{FORMAT_VERSION} — re-record it (ct oracle \
                   bless)");
        }
        let spec = FixtureSpec::from_value(v.get("spec"))?;
        if spec.name != name {
            bail!("fixture file {name:?} contains spec named {:?}",
                  spec.name);
        }
        let responses: Vec<RespMeta> = v.get("responses")
            .as_arr()
            .ok_or_else(|| anyhow!("fixture {name:?}: missing responses"))?
            .iter()
            .map(RespMeta::from_value)
            .collect::<Result<_>>()?;
        let metrics = MetricsSnapshot::from_value(v.get("metrics"))?;
        let total_elems = v.get("frames")
            .get("total_elems")
            .as_usize()
            .ok_or_else(|| anyhow!("fixture {name:?}: missing frame \
                                    count"))?;
        let want_sum = fnv1a64(&[]);
        let want_sum = match v.get("frames").get("fnv1a64") {
            Value::Null => want_sum, // tolerated only for empty streams
            s => parse_hex_u64(s)?,
        };
        let fp = Self::frames_path(dir, name);
        let bytes = std::fs::read(&fp)
            .map_err(|e| anyhow!("read {}: {e}", fp.display()))?;
        if bytes.len() != total_elems * 4 {
            bail!("fixture {name:?}: frame file is {} bytes, header \
                   says {} elems ({} bytes) — truncated or stale",
                  bytes.len(), total_elems, total_elems * 4);
        }
        let got_sum = fnv1a64(&bytes);
        if got_sum != want_sum {
            bail!("fixture {name:?}: frame checksum {} != header {} — \
                   corrupt or stale frame file",
                  hex_u64(got_sum), hex_u64(want_sum));
        }
        let frames: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let claimed: usize = responses.iter().map(|r| r.elems).sum();
        if claimed != frames.len() {
            bail!("fixture {name:?}: responses claim {claimed} elems, \
                   frame file holds {}", frames.len());
        }
        Ok(Self { spec, responses, metrics, frames })
    }
}

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

/// The fixture directory's index: sorted fixture names.  Kept sorted on
/// every save so re-recording a suite never reorders the file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    pub fixtures: Vec<String>,
}

impl Manifest {
    pub fn path(dir: &Path) -> PathBuf {
        dir.join("manifest.json")
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let p = Self::path(dir);
        if !p.exists() {
            return Ok(Self::default());
        }
        let text = std::fs::read_to_string(&p)
            .map_err(|e| anyhow!("read {}: {e}", p.display()))?;
        let v = jsonio::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", p.display()))?;
        let version = v.get("format_version").as_usize().unwrap_or(0);
        if version != FORMAT_VERSION as usize {
            bail!("manifest is format v{version}, this build reads \
                   v{FORMAT_VERSION}");
        }
        Ok(Self {
            fixtures: v.get("fixtures")
                .as_arr()
                .ok_or_else(|| anyhow!("manifest: missing fixtures"))?
                .iter()
                .map(|f| f.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("manifest: bad fixture name")))
                .collect::<Result<_>>()?,
        })
    }

    pub fn add(&mut self, name: &str) {
        if !self.fixtures.iter().any(|f| f == name) {
            self.fixtures.push(name.to_string());
        }
        self.fixtures.sort();
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut sorted = self.fixtures.clone();
        sorted.sort();
        let v = obj(vec![
            ("format_version", (FORMAT_VERSION as usize).into()),
            ("fixtures", Value::Arr(
                sorted.iter().map(|f| f.as_str().into()).collect())),
        ]);
        std::fs::write(Self::path(dir), jsonio::to_string_pretty(&v))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> FixtureSpec {
        FixtureSpec {
            name: "demo-mixed".into(),
            kernel: "full".into(),
            heads: 2,
            dk: 4,
            dv: 4,
            buckets: vec![8, 16],
            seed: 0xDEAD_BEEF_0000_0001,
            masked: true,
            causal: false,
            shards: 0,
            trace: TraceSpec::Mixed {
                min_len: 2, max_len: 12, count: 5,
                prefill: 4, steps: 2, step_len: 2, sessions: 2,
            },
        }
    }

    #[test]
    fn spec_roundtrips_through_json() {
        let spec = demo_spec();
        let text = jsonio::to_string(&spec.to_value());
        let v = jsonio::parse(&text).unwrap();
        assert_eq!(FixtureSpec::from_value(&v).unwrap(), spec);
        // causal is emitted only when true, so pre-causal headers stay
        // byte-stable — and absent parses as false
        assert!(!text.contains("causal"));
        let causal = FixtureSpec { causal: true, ..demo_spec() };
        let text = jsonio::to_string(&causal.to_value());
        assert!(text.contains("\"causal\":true"));
        let v = jsonio::parse(&text).unwrap();
        assert_eq!(FixtureSpec::from_value(&v).unwrap(), causal);
    }

    #[test]
    fn trace_generation_is_deterministic_and_mixed_interleaves() {
        let spec = demo_spec();
        let shape = spec.shape();
        let a = spec.trace.generate(shape, spec.seed);
        let b = spec.trace.generate(shape, spec.seed);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len, y.len);
            assert_eq!(x.session, y.session);
            assert_eq!(x.q, y.q);
        }
        // 5 one-shots + 2 sessions × (prefill + 2 steps)
        assert_eq!(a.len(), 5 + 2 * 3);
        assert!(a.iter().any(|i| i.session.is_some()));
        assert!(a.iter().any(|i| i.session.is_none()));
        // interleaved, not concatenated: a session step appears before
        // the last one-shot
        let first_session =
            a.iter().position(|i| i.session.is_some()).unwrap();
        let last_shot =
            a.iter().rposition(|i| i.session.is_none()).unwrap();
        assert!(first_session < last_shot);
    }

    #[test]
    fn identity_trace_is_the_documented_closed_form() {
        let shape = GatewayShape { heads: 2, dk: 4, dv: 4 };
        let items =
            TraceSpec::IdentityLen1 { count: 3 }.generate(shape, 0);
        assert_eq!(items.len(), 3);
        for (r, item) in items.iter().enumerate() {
            assert_eq!(item.len, 1);
            assert_eq!(item.v.len(), shape.v_len(1));
            for (j, &x) in item.v.iter().enumerate() {
                assert_eq!(x.to_bits(),
                           pattern_value(2, r, j).to_bits());
            }
        }
        // the formula itself, pinned: (0*31 + 0*7 + 2*13) % 251 = 26
        assert_eq!(pattern_value(2, 0, 0), 26.0 * 0.015625);
        let expected = identity_expected_frames(shape, 3);
        assert_eq!(expected.len(), 3 * shape.v_len(1));
        assert_eq!(expected[0].to_bits(),
                   pattern_value(2, 0, 0).to_bits());
    }

    #[test]
    fn fixture_files_roundtrip_and_checksum_catches_corruption() {
        let dir = std::env::temp_dir()
            .join(format!("ct-oracle-fixture-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fx = Fixture {
            spec: FixtureSpec {
                name: "roundtrip".into(),
                trace: TraceSpec::IdentityLen1 { count: 2 },
                ..demo_spec()
            },
            responses: vec![
                RespMeta { len: 1, span_start: 0, session: None,
                           cache_hit: None, bucket_n: 8, elems: 3 },
                RespMeta { len: 1, span_start: 0,
                           session: Some(0xFFFF_FFFF_FFFF_FFFE),
                           cache_hit: Some(true), bucket_n: 8,
                           elems: 2 },
            ],
            metrics: MetricsSnapshot {
                completed: vec![2, 0],
                cache_hits: 1,
                ..MetricsSnapshot::default()
            },
            frames: vec![1.0, -0.5, 3.25, f32::MIN_POSITIVE, 0.0],
        };
        fx.save(&dir).unwrap();
        // byte-stable: a second save writes identical files
        let header = dir.join("roundtrip.json");
        let before = std::fs::read(&header).unwrap();
        fx.save(&dir).unwrap();
        assert_eq!(before, std::fs::read(&header).unwrap());
        let loaded = Fixture::load(&dir, "roundtrip").unwrap();
        assert_eq!(loaded, fx);
        // corrupt one frame byte → load must refuse
        let bin = dir.join("roundtrip.bin");
        let mut bytes = std::fs::read(&bin).unwrap();
        bytes[5] ^= 0x01;
        std::fs::write(&bin, &bytes).unwrap();
        let err = Fixture::load(&dir, "roundtrip").unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err:#}");
        // truncation → load must refuse
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        let err = Fixture::load(&dir, "roundtrip").unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_sorts_and_dedups() {
        let dir = std::env::temp_dir()
            .join(format!("ct-oracle-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut m = Manifest::default();
        m.add("zeta");
        m.add("alpha");
        m.add("zeta");
        assert_eq!(m.fixtures, vec!["alpha", "zeta"]);
        m.save(&dir).unwrap();
        let before = std::fs::read(Manifest::path(&dir)).unwrap();
        m.save(&dir).unwrap();
        assert_eq!(before, std::fs::read(Manifest::path(&dir)).unwrap());
        assert_eq!(Manifest::load(&dir).unwrap(), m);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }
}
