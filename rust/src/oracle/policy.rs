//! ct-contract: panic-free
//!
//! Tolerance policy: what the replay diff and the perf gate are
//! allowed to forgive.
//!
//! The policy lives in a checked-in file (`oracle/tolerance-policy.json`
//! at the repo root) so loosening a gate is a reviewed diff, not a CI
//! knob.  The defaults are the strictest settings — everything the
//! serving stack produces deterministically is held bit-exact /
//! count-exact, and only wall-clock throughput gets a tolerance band:
//!
//! ```json
//! {
//!   "version": 1,
//!   "output_bits": "exact",
//!   "require_bucket_match": true,
//!   "require_cache_hit_match": true,
//!   "require_counter_match": true,
//!   "max_bench_regression": 0.15
//! }
//! ```
//!
//! `output_bits` declares how outputs are compared.  `"exact"` (the
//! default, and what the checked-in policy pins) is the bit-identity
//! contract: fixture replay and every determinism property hold bits
//! equal.  The quantized KV cache (`--cache-quant`) is the repo's
//! first sanctioned departure from bit-identity, so `output_bits` also
//! accepts a numeric-tolerance object:
//!
//! ```json
//! { "output_bits": { "abs_tol": 0.05, "rel_tol": 0.15 } }
//! ```
//!
//! which admits `|got − want| ≤ abs_tol + rel_tol · |want|` per
//! element ([`OutputBits::allows`]).  The tolerance mode gates the
//! quantized proptests and the bench error column; the checked-in
//! fixture corpus was recorded unquantized and still replays
//! bit-exactly.  Any other string (e.g. `"ulp-2"`) is rejected.
//! Unknown keys are rejected — a typoed knob must fail loudly, not
//! silently gate nothing.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::jsonio::{self, obj, Value};

/// How outputs are compared: bit-exact (the default contract) or
/// within a declared numeric tolerance (the quantized-cache mode).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum OutputBits {
    /// Outputs must be bit-identical to the reference.
    #[default]
    Exact,
    /// Outputs must satisfy `|got − want| ≤ abs_tol + rel_tol·|want|`
    /// per element — the band quantized decode is held to.
    Tolerance { abs_tol: f64, rel_tol: f64 },
}

impl OutputBits {
    /// Does an observed absolute error pass, given the magnitude of
    /// the reference value it was measured against?  `Exact` admits
    /// only zero error.
    pub fn allows(&self, err: f64, ref_mag: f64) -> bool {
        match *self {
            OutputBits::Exact => err == 0.0,
            OutputBits::Tolerance { abs_tol, rel_tol } => {
                err <= abs_tol + rel_tol * ref_mag.abs()
            }
        }
    }
}

/// Parsed tolerance policy; see the module docs for field meaning.
#[derive(Debug, Clone, PartialEq)]
pub struct TolerancePolicy {
    /// Output comparison mode: bit-exact, or a numeric tolerance band
    /// for quantized decode.
    pub output_bits: OutputBits,
    /// Fail a fixture whose response lands in a different bucket.
    pub require_bucket_match: bool,
    /// Fail a fixture whose decode steps change cache-hit/miss flags.
    pub require_cache_hit_match: bool,
    /// Fail a fixture whose deterministic metric counters drift.
    pub require_counter_match: bool,
    /// Perf gate: fail when fresh rows/sec drops below
    /// `baseline · (1 − max_bench_regression)`.
    pub max_bench_regression: f64,
}

impl Default for TolerancePolicy {
    fn default() -> Self {
        Self {
            output_bits: OutputBits::Exact,
            require_bucket_match: true,
            require_cache_hit_match: true,
            require_counter_match: true,
            max_bench_regression: 0.15,
        }
    }
}

impl TolerancePolicy {
    /// Load the policy file; a missing file means the strict defaults.
    pub fn load(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::default());
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let v = jsonio::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        Self::from_value(&v)
            .map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let o = v.as_obj()
            .ok_or_else(|| anyhow!("policy must be a JSON object"))?;
        let mut policy = Self::default();
        for (key, val) in o {
            match key.as_str() {
                "version" => {
                    if val.as_usize() != Some(1) {
                        bail!("unsupported policy version {val:?}");
                    }
                }
                "output_bits" => {
                    policy.output_bits = parse_output_bits(val)?;
                }
                "require_bucket_match" => {
                    policy.require_bucket_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_bucket_match \
                                                must be a bool"))?;
                }
                "require_cache_hit_match" => {
                    policy.require_cache_hit_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_cache_hit_match \
                                                must be a bool"))?;
                }
                "require_counter_match" => {
                    policy.require_counter_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_counter_match \
                                                must be a bool"))?;
                }
                "max_bench_regression" => {
                    let f = val.as_f64().ok_or_else(
                        || anyhow!("max_bench_regression must be a \
                                    number"))?;
                    if !(0.0..1.0).contains(&f) {
                        bail!("max_bench_regression {f} outside [0, 1)");
                    }
                    policy.max_bench_regression = f;
                }
                other => bail!("unknown policy key {other:?} (typo? \
                                known keys: version, output_bits, \
                                require_bucket_match, \
                                require_cache_hit_match, \
                                require_counter_match, \
                                max_bench_regression)"),
            }
        }
        Ok(policy)
    }

    /// The canonical serialized form (what `docs/TESTING.md` tells
    /// operators to check in).
    pub fn to_value(&self) -> Value {
        let bits = match self.output_bits {
            OutputBits::Exact => "exact".into(),
            OutputBits::Tolerance { abs_tol, rel_tol } => obj(vec![
                ("abs_tol", abs_tol.into()),
                ("rel_tol", rel_tol.into()),
            ]),
        };
        obj(vec![
            ("version", 1usize.into()),
            ("output_bits", bits),
            ("require_bucket_match", self.require_bucket_match.into()),
            ("require_cache_hit_match",
             self.require_cache_hit_match.into()),
            ("require_counter_match",
             self.require_counter_match.into()),
            ("max_bench_regression", self.max_bench_regression.into()),
        ])
    }
}

/// Parse the `output_bits` field: the string `"exact"`, or an object
/// `{"abs_tol": a, "rel_tol": r}` with both keys present, finite and
/// non-negative.  Anything else — including other strings such as
/// `"ulp-2"` — is rejected loudly.
fn parse_output_bits(val: &Value) -> Result<OutputBits> {
    if let Some(s) = val.as_str() {
        if s == "exact" {
            return Ok(OutputBits::Exact);
        }
        bail!("output_bits {s:?} unsupported — use \"exact\" or \
               {{\"abs_tol\", \"rel_tol\"}}");
    }
    let o = val.as_obj().ok_or_else(
        || anyhow!("output_bits must be \"exact\" or an object with \
                    abs_tol and rel_tol"))?;
    let mut abs_tol = None;
    let mut rel_tol = None;
    for (key, v) in o {
        let f = v.as_f64().ok_or_else(
            || anyhow!("output_bits.{key} must be a number"))?;
        if !f.is_finite() || f < 0.0 {
            bail!("output_bits.{key} {f} must be finite and >= 0");
        }
        match key.as_str() {
            "abs_tol" => abs_tol = Some(f),
            "rel_tol" => rel_tol = Some(f),
            other => bail!("unknown output_bits key {other:?} (known \
                            keys: abs_tol, rel_tol)"),
        }
    }
    match (abs_tol, rel_tol) {
        (Some(abs_tol), Some(rel_tol)) => {
            Ok(OutputBits::Tolerance { abs_tol, rel_tol })
        }
        _ => bail!("output_bits object needs both abs_tol and rel_tol"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_file_means_strict_defaults() {
        let p = std::env::temp_dir().join(format!(
            "ct-oracle-no-such-policy-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&p);
        assert_eq!(TolerancePolicy::load(&p).unwrap(),
                   TolerancePolicy::default());
    }

    #[test]
    fn canonical_form_roundtrips() {
        let policy = TolerancePolicy {
            max_bench_regression: 0.25,
            require_cache_hit_match: false,
            ..TolerancePolicy::default()
        };
        let v = jsonio::parse(&jsonio::to_string_pretty(
            &policy.to_value())).unwrap();
        assert_eq!(TolerancePolicy::from_value(&v).unwrap(), policy);
    }

    #[test]
    fn unknown_keys_and_bad_modes_are_rejected() {
        let v = jsonio::parse(
            r#"{"version": 1, "max_bench_regresion": 0.2}"#).unwrap();
        let err = TolerancePolicy::from_value(&v).unwrap_err();
        assert!(err.to_string().contains("unknown policy key"),
                "{err:#}");
        let v = jsonio::parse(
            r#"{"output_bits": "ulp-2"}"#).unwrap();
        assert!(TolerancePolicy::from_value(&v).is_err());
        let v = jsonio::parse(
            r#"{"max_bench_regression": 1.5}"#).unwrap();
        assert!(TolerancePolicy::from_value(&v).is_err());
    }

    #[test]
    fn tolerance_mode_parses_allows_and_roundtrips() {
        let v = jsonio::parse(
            r#"{"output_bits": {"abs_tol": 0.05, "rel_tol": 0.15}}"#)
            .unwrap();
        let policy = TolerancePolicy::from_value(&v).unwrap();
        let bits = policy.output_bits;
        assert_eq!(bits, OutputBits::Tolerance { abs_tol: 0.05,
                                                 rel_tol: 0.15 });
        // the band is abs + rel·|ref|
        assert!(bits.allows(0.04, 0.0));
        assert!(bits.allows(0.19, 1.0));
        assert!(!bits.allows(0.21, 1.0));
        assert!(bits.allows(0.19, -1.0)); // magnitude, not sign
        // exact admits only zero error
        assert!(OutputBits::Exact.allows(0.0, 3.0));
        assert!(!OutputBits::Exact.allows(1e-9, 3.0));
        // canonical form round-trips through jsonio byte-stably
        let text = jsonio::to_string_pretty(&policy.to_value());
        let back = TolerancePolicy::from_value(
            &jsonio::parse(&text).unwrap()).unwrap();
        assert_eq!(back, policy);
        assert_eq!(jsonio::to_string_pretty(&back.to_value()), text);
    }

    #[test]
    fn malformed_tolerance_objects_are_rejected() {
        for bad in [r#"{"output_bits": {"abs_tol": 0.05}}"#,
                    r#"{"output_bits": {"abs_tol": 0.1, "rel": 0.1}}"#,
                    r#"{"output_bits": {"abs_tol": -0.1, "rel_tol": 0}}"#,
                    r#"{"output_bits": {"abs_tol": true, "rel_tol": 0}}"#,
                    r#"{"output_bits": 3}"#] {
            let v = jsonio::parse(bad).unwrap();
            assert!(TolerancePolicy::from_value(&v).is_err(), "{bad}");
        }
    }
}
