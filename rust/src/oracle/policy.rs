//! ct-contract: panic-free
//!
//! Tolerance policy: what the replay diff and the perf gate are
//! allowed to forgive.
//!
//! The policy lives in a checked-in file (`oracle/tolerance-policy.json`
//! at the repo root) so loosening a gate is a reviewed diff, not a CI
//! knob.  The defaults are the strictest settings — everything the
//! serving stack produces deterministically is held bit-exact /
//! count-exact, and only wall-clock throughput gets a tolerance band:
//!
//! ```json
//! {
//!   "version": 1,
//!   "output_bits": "exact",
//!   "require_bucket_match": true,
//!   "require_cache_hit_match": true,
//!   "require_counter_match": true,
//!   "max_bench_regression": 0.15
//! }
//! ```
//!
//! `output_bits` is declarative on purpose: `"exact"` is the only mode
//! this build implements (the gateway's parity contract is bit-exact),
//! but the field keeps the file forward-compatible with an approximate
//! mode should a future kernel need ULP bands.  Unknown keys are
//! rejected — a typoed knob must fail loudly, not silently gate
//! nothing.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::jsonio::{self, obj, Value};

/// Parsed tolerance policy; see the module docs for field meaning.
#[derive(Debug, Clone, PartialEq)]
pub struct TolerancePolicy {
    /// Fail a fixture whose response lands in a different bucket.
    pub require_bucket_match: bool,
    /// Fail a fixture whose decode steps change cache-hit/miss flags.
    pub require_cache_hit_match: bool,
    /// Fail a fixture whose deterministic metric counters drift.
    pub require_counter_match: bool,
    /// Perf gate: fail when fresh rows/sec drops below
    /// `baseline · (1 − max_bench_regression)`.
    pub max_bench_regression: f64,
}

impl Default for TolerancePolicy {
    fn default() -> Self {
        Self {
            require_bucket_match: true,
            require_cache_hit_match: true,
            require_counter_match: true,
            max_bench_regression: 0.15,
        }
    }
}

impl TolerancePolicy {
    /// Load the policy file; a missing file means the strict defaults.
    pub fn load(path: &Path) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::default());
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let v = jsonio::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        Self::from_value(&v)
            .map_err(|e| anyhow!("{}: {e}", path.display()))
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let o = v.as_obj()
            .ok_or_else(|| anyhow!("policy must be a JSON object"))?;
        let mut policy = Self::default();
        for (key, val) in o {
            match key.as_str() {
                "version" => {
                    if val.as_usize() != Some(1) {
                        bail!("unsupported policy version {val:?}");
                    }
                }
                "output_bits" => {
                    if val.as_str() != Some("exact") {
                        bail!("output_bits {val:?} unsupported — this \
                               build only implements \"exact\"");
                    }
                }
                "require_bucket_match" => {
                    policy.require_bucket_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_bucket_match \
                                                must be a bool"))?;
                }
                "require_cache_hit_match" => {
                    policy.require_cache_hit_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_cache_hit_match \
                                                must be a bool"))?;
                }
                "require_counter_match" => {
                    policy.require_counter_match = val.as_bool()
                        .ok_or_else(|| anyhow!("require_counter_match \
                                                must be a bool"))?;
                }
                "max_bench_regression" => {
                    let f = val.as_f64().ok_or_else(
                        || anyhow!("max_bench_regression must be a \
                                    number"))?;
                    if !(0.0..1.0).contains(&f) {
                        bail!("max_bench_regression {f} outside [0, 1)");
                    }
                    policy.max_bench_regression = f;
                }
                other => bail!("unknown policy key {other:?} (typo? \
                                known keys: version, output_bits, \
                                require_bucket_match, \
                                require_cache_hit_match, \
                                require_counter_match, \
                                max_bench_regression)"),
            }
        }
        Ok(policy)
    }

    /// The canonical serialized form (what `docs/TESTING.md` tells
    /// operators to check in).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("version", 1usize.into()),
            ("output_bits", "exact".into()),
            ("require_bucket_match", self.require_bucket_match.into()),
            ("require_cache_hit_match",
             self.require_cache_hit_match.into()),
            ("require_counter_match",
             self.require_counter_match.into()),
            ("max_bench_regression", self.max_bench_regression.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_file_means_strict_defaults() {
        let p = std::env::temp_dir().join(format!(
            "ct-oracle-no-such-policy-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&p);
        assert_eq!(TolerancePolicy::load(&p).unwrap(),
                   TolerancePolicy::default());
    }

    #[test]
    fn canonical_form_roundtrips() {
        let policy = TolerancePolicy {
            max_bench_regression: 0.25,
            require_cache_hit_match: false,
            ..TolerancePolicy::default()
        };
        let v = jsonio::parse(&jsonio::to_string_pretty(
            &policy.to_value())).unwrap();
        assert_eq!(TolerancePolicy::from_value(&v).unwrap(), policy);
    }

    #[test]
    fn unknown_keys_and_bad_modes_are_rejected() {
        let v = jsonio::parse(
            r#"{"version": 1, "max_bench_regresion": 0.2}"#).unwrap();
        let err = TolerancePolicy::from_value(&v).unwrap_err();
        assert!(err.to_string().contains("unknown policy key"),
                "{err:#}");
        let v = jsonio::parse(
            r#"{"output_bits": "ulp-2"}"#).unwrap();
        assert!(TolerancePolicy::from_value(&v).is_err());
        let v = jsonio::parse(
            r#"{"max_bench_regression": 1.5}"#).unwrap();
        assert!(TolerancePolicy::from_value(&v).is_err());
    }
}
