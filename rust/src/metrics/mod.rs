//! Evaluation metrics: edit distance (PER/WER), accuracy/F1, latency
//! histograms + percentile summaries for the serving path.

/// Levenshtein distance between two label sequences.
pub fn edit_distance(a: &[i32], b: &[i32]) -> usize {
    let (la, lb) = (a.len(), b.len());
    if la == 0 {
        return lb;
    }
    if lb == 0 {
        return la;
    }
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let sub = prev[j - 1] + (a[i - 1] != b[j - 1]) as usize;
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

/// Accumulates token error rate (PER/WER) over utterances.
#[derive(Debug, Default, Clone)]
pub struct ErrorRate {
    pub errors: usize,
    pub tokens: usize,
}

impl ErrorRate {
    pub fn add(&mut self, hyp: &[i32], refr: &[i32]) {
        self.errors += edit_distance(hyp, refr);
        self.tokens += refr.len();
    }

    /// Error rate in percent (the paper's PER/WER convention).
    pub fn percent(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            100.0 * self.errors as f64 / self.tokens as f64
        }
    }
}

/// Binary/multi-class accuracy accumulator.
#[derive(Debug, Default, Clone)]
pub struct Accuracy {
    pub correct: usize,
    pub total: usize,
}

impl Accuracy {
    pub fn add(&mut self, pred: i32, target: i32) {
        self.total += 1;
        self.correct += (pred == target) as usize;
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

/// Span-extraction F1 in the SQuAD style (token-overlap of spans).
pub fn span_f1(pred: (i32, i32), gold: (i32, i32)) -> f64 {
    let (ps, pe) = (pred.0.min(pred.1), pred.0.max(pred.1));
    let (gs, ge) = (gold.0, gold.1);
    let inter = (pe.min(ge) - ps.max(gs)).max(0) as f64;
    let plen = (pe - ps).max(0) as f64;
    let glen = (ge - gs).max(0) as f64;
    if inter == 0.0 || plen == 0.0 || glen == 0.0 {
        return if plen == glen && ps == gs { 1.0 } else { 0.0 };
    }
    let p = inter / plen;
    let r = inter / glen;
    2.0 * p * r / (p + r)
}

/// Padding-waste accumulator for static-shape serving.
///
/// A length-bucketed engine pads every request of `len` valid rows up to
/// its bucket's `seq_len`.  Two different costs hide in that padding and
/// this accumulator tracks both:
///
/// - **memory-padding waste** ([`memory_ratio`]) — the fraction of rows
///   in the padded batch buffers that are padding.  Static shapes always
///   pay this: the (B, H, N, D) tensors are allocated at bucket size no
///   matter what the kernels later touch.
/// - **masked-compute waste** ([`compute_ratio`]) — the fraction of rows
///   the kernels actually *executed* that were padding.  With
///   valid-length masking on, kernels skip padded rows entirely, this
///   drops to zero, and the flip side — [`compute_saved`], the fraction
///   of padded rows never executed — measures what masking bought.
///
/// Accumulated per bucket by the serving gateway and reported next to
/// latency percentiles, because waste is the price paid for static
/// shapes and bucket sizing (plus masking) is the dial.
///
/// [`memory_ratio`]: PaddingWaste::memory_ratio
/// [`compute_ratio`]: PaddingWaste::compute_ratio
/// [`compute_saved`]: PaddingWaste::compute_saved
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PaddingWaste {
    /// Valid (request) rows.
    pub valid: u64,
    /// Rows in the padded batch buffers (`Σ bucket seq_len`).
    pub padded: u64,
    /// Rows the kernels actually executed (`Σ len` when masked,
    /// `Σ seq_len` when not).
    pub computed: u64,
}

impl PaddingWaste {
    /// Record one *unmasked* request: `len` valid rows padded to
    /// `seq_len`, all `seq_len` rows executed.
    pub fn add(&mut self, len: usize, seq_len: usize) {
        self.valid += len as u64;
        self.padded += seq_len as u64;
        self.computed += seq_len as u64;
    }

    /// Record one *masked* request: `len` valid rows padded to
    /// `seq_len`, only the `len` valid rows executed.
    pub fn add_masked(&mut self, len: usize, seq_len: usize) {
        self.valid += len as u64;
        self.padded += seq_len as u64;
        self.computed += len as u64;
    }

    /// Fraction of padded-buffer rows that were padding, in [0, 1] —
    /// the memory cost of static shapes (masking cannot reduce it).
    pub fn memory_ratio(&self) -> f64 {
        if self.padded == 0 {
            0.0
        } else {
            1.0 - self.valid as f64 / self.padded as f64
        }
    }

    /// Back-compat alias of [`PaddingWaste::memory_ratio`] (the only
    /// waste there was before masked compute existed).
    pub fn ratio(&self) -> f64 {
        self.memory_ratio()
    }

    /// Fraction of *executed* rows that were padding, in [0, 1] — zero
    /// when masking skips every padded row.
    pub fn compute_ratio(&self) -> f64 {
        if self.computed == 0 {
            0.0
        } else {
            1.0 - self.valid as f64 / self.computed as f64
        }
    }

    /// Fraction of padded-buffer rows the kernels never executed, in
    /// [0, 1] — the compute masking saved.
    pub fn compute_saved(&self) -> f64 {
        if self.padded == 0 {
            0.0
        } else {
            1.0 - self.computed as f64 / self.padded as f64
        }
    }
}

/// Fixed-boundary latency histogram (µs buckets, power-of-√2 spacing).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>, // exact values for percentile queries (bounded)
    max_samples: usize,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1.0;
        while b < 60_000_000.0 {
            bounds.push(b);
            b *= std::f64::consts::SQRT_2;
        }
        let n = bounds.len();
        Self { bounds_us: bounds, counts: vec![0; n + 1],
               samples: Vec::new(), max_samples: 100_000 }
    }

    pub fn record(&mut self, dur: std::time::Duration) {
        let us = dur.as_secs_f64() * 1e6;
        let idx = self
            .bounds_us
            .partition_point(|&b| b < us);
        self.counts[idx] += 1;
        if self.samples.len() < self.max_samples {
            self.samples.push(us);
        }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Percentile in microseconds (exact over retained samples).
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}µs p50={:.0}µs p95={:.0}µs p99={:.0}µs",
            self.count(),
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn edit_distance_known_cases() {
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 2], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
        assert_eq!(edit_distance(&[3, 1, 4, 1, 5], &[1, 4, 1]), 2);
    }

    #[test]
    fn edit_distance_symmetric_and_triangle() {
        let a = [1, 5, 2, 7];
        let b = [5, 2, 9];
        let c = [5, 9];
        let ab = edit_distance(&a, &b);
        assert_eq!(ab, edit_distance(&b, &a));
        assert!(edit_distance(&a, &c) <= ab + edit_distance(&b, &c));
    }

    #[test]
    fn per_percent() {
        let mut er = ErrorRate::default();
        er.add(&[1, 2, 3], &[1, 2, 4]); // 1 error / 3
        er.add(&[1], &[1]); // 0 / 1
        assert!((er.percent() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn span_f1_cases() {
        assert_eq!(span_f1((5, 8), (5, 8)), 1.0);
        assert_eq!(span_f1((0, 2), (5, 8)), 0.0);
        let f1 = span_f1((5, 7), (5, 8)); // overlap 2, p=1, r=2/3
        assert!((f1 - 0.8).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_us(50.0);
        assert!((45_000.0..56_000.0).contains(&p50), "{p50}");
        let p99 = h.percentile_us(99.0);
        assert!(p99 >= 98_000.0, "{p99}");
    }

    #[test]
    fn padding_waste_ratio() {
        let mut w = PaddingWaste::default();
        assert_eq!(w.ratio(), 0.0); // empty: no waste, not NaN
        w.add(64, 64); // exact fit
        assert!(w.ratio() < 1e-12);
        w.add(32, 64); // half padding
        // 96 valid of 128 executed -> 25% waste
        assert!((w.ratio() - 0.25).abs() < 1e-12);
        w.add(0, 64); // degenerate empty request is pure waste
        assert!((w.ratio() - (1.0 - 96.0 / 192.0)).abs() < 1e-12);
        // unmasked: every padded row was executed, nothing saved
        assert!((w.compute_ratio() - w.memory_ratio()).abs() < 1e-12);
        assert_eq!(w.compute_saved(), 0.0);
    }

    #[test]
    fn masked_requests_split_memory_and_compute_waste() {
        let mut w = PaddingWaste::default();
        assert_eq!(w.compute_ratio(), 0.0); // empty: 0, not NaN
        assert_eq!(w.compute_saved(), 0.0);
        w.add_masked(32, 64);
        w.add_masked(64, 64);
        // buffers still carry the padding...
        assert!((w.memory_ratio() - 0.25).abs() < 1e-12);
        // ...but the kernels executed only valid rows
        assert_eq!(w.compute_ratio(), 0.0);
        assert!((w.compute_saved() - 0.25).abs() < 1e-12);
        // a mixed masked/unmasked stream accounts each request its way
        w.add(32, 64); // unmasked spill: executes its padding
        assert!(w.compute_ratio() > 0.0);
        assert!(w.compute_saved() > 0.0);
        assert_eq!(w.computed, 32 + 64 + 64);
        assert_eq!(w.padded, 192);
        assert_eq!(w.valid, 128);
    }

    #[test]
    fn accuracy_accumulates() {
        let mut a = Accuracy::default();
        a.add(1, 1);
        a.add(0, 1);
        a.add(1, 1);
        assert!((a.value() - 2.0 / 3.0).abs() < 1e-12);
    }
}
