//! ct-contract: panic-free
//! ct-lint: allow(det-entropy, reason = "Instant::now feeds deadline batching and latency metrics only — never the math")
//! ct-lint: allow(panic-index, reason = "engine indexing derives from shape invariants validated at submit; new code should prefer get()")
//!
//! The inference engine: router → per-bucket dynamic batcher → worker
//! threads executing compiled forward programs → responses.
//!
//! One dispatcher thread per bucket owns that bucket's batcher and
//! executable; the shared ingress queue provides backpressure (bounded —
//! `submit` blocks or fails fast when the system is saturated).
//!
//! Two engines share the batcher/metrics machinery:
//! [`InferenceEngine`] executes compiled HLO through PJRT — its forward
//! programs take the per-request lengths as their `xlen` input and mask
//! ragged sequences inside the graph — and [`NativeAttentionEngine`]
//! batches multi-head attention requests into (B, H, N, D) descriptors
//! and executes them through the [`NativeBackend`] seam over the exec
//! worker pool — no artifacts or native XLA required.  Both paths
//! consume the same request information; an HLO raw-attention
//! executable wrapped in `attention::AttentionBackend` is the drop-in
//! bridge between them.

// The panic-free serving contract, compiler-side: `ct lint` scans the
// source, clippy guards what the scanner cannot see through macros.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::attention::{AttentionBackend, AttentionKernel, AttnBatch,
                       NativeBackend};
use crate::exec::{Channel, ExecCtx, WorkerPool};
use crate::metrics::LatencyHistogram;
use crate::runtime::{HostTensor, Runtime};
use crate::tensor::batch::BatchMatrix;

use super::batcher::{BatchPolicy, Batcher};
use super::router::{Bucket, Router};

/// An inference request: `frames` is (len × d_feat) row-major features
/// (ASR) — the engine pads it into the bucket's static shape.
pub struct Request {
    pub id: u64,
    pub frames: Vec<f32>,
    pub len: usize,
    pub d_feat: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// Per-request result: the logits rows for the valid frames.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub valid_len: usize,
    pub queue_time: Duration,
    pub total_time: Duration,
    pub batch_occupancy: usize,
}

#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub policy: BatchPolicy,
    pub queue_capacity: usize,
    pub params_seed: i32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), queue_capacity: 64,
               params_seed: 0 }
    }
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct ServeMetrics {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub latency: Mutex<LatencyHistogram>,
}

impl ServeMetrics {
    pub fn occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }
}

pub struct InferenceEngine {
    router: Router,
    ingress: Vec<Channel<Request>>, // one per bucket
    pub metrics: Arc<ServeMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl InferenceEngine {
    /// Build from forward programs (one per bucket) and model params.
    ///
    /// The `xla` crate's PJRT client is `Rc`-based (not `Send`), so each
    /// dispatcher thread opens its *own* `Runtime` on `artifacts_dir` and
    /// compiles its bucket's executable locally — no client ever crosses
    /// a thread boundary.
    pub fn start(rt: &Runtime, programs: &[String], params: Vec<f32>,
                 opts: ServeOptions) -> Result<Self> {
        let mut buckets = Vec::new();
        for name in programs {
            let p = rt.program(name)?;
            buckets.push(Bucket::hlo(name.clone(), p.seq_len(),
                                     p.batch_size()));
        }
        let artifacts_dir = rt.dir.clone();
        let router = Router::new(buckets)?;
        let metrics = Arc::new(ServeMetrics::default());
        let params = Arc::new(params);

        let mut ingress = Vec::new();
        let mut workers = Vec::new();
        for bucket in router.buckets() {
            let ch: Channel<Request> = Channel::bounded(opts.queue_capacity);
            ingress.push(ch.clone());
            let dir = artifacts_dir.clone();
            let bucket = bucket.clone();
            let metrics = metrics.clone();
            let params = params.clone();
            let policy = opts.policy;
            let seed = opts.params_seed;
            workers.push(std::thread::Builder::new()
                .name(format!("ct-dispatch-{}", bucket.seq_len))
                .spawn(move || {
                    let rt = match Runtime::open(&dir) {
                        Ok(rt) => rt,
                        Err(e) => {
                            log::error!("dispatcher runtime: {e:#}");
                            return;
                        }
                    };
                    dispatcher(rt, bucket, ch, metrics, params, policy, seed)
                })?);
        }
        Ok(Self { router, ingress, metrics, workers,
                  next_id: AtomicU64::new(0) })
    }

    /// Submit a request; the response arrives on the returned receiver.
    /// Fails fast when the request is too long or the queue is full
    /// (backpressure surfaces to the caller, as a real router would 429).
    pub fn submit(&self, frames: Vec<f32>, len: usize, d_feat: usize)
                  -> Result<mpsc::Receiver<Response>> {
        let idx = self
            .router
            .route_index(len)
            .ok_or_else(|| anyhow!("request of length {len} exceeds every \
                                    bucket"))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            frames,
            len,
            d_feat,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.ingress[idx].try_send(req).map_err(|_| {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow!("bucket {idx} queue full (backpressure)")
        })?;
        Ok(rx)
    }

    /// Blocking submit (waits out backpressure instead of failing).
    pub fn submit_blocking(&self, frames: Vec<f32>, len: usize,
                           d_feat: usize) -> Result<mpsc::Receiver<Response>> {
        let idx = self
            .router
            .route_index(len)
            .ok_or_else(|| anyhow!("request too long"))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            frames,
            len,
            d_feat,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.ingress[idx]
            .send(req)
            .map_err(|_| anyhow!("engine shut down"))?;
        Ok(rx)
    }

    pub fn shutdown(self) {
        for ch in &self.ingress {
            ch.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Per-bucket dispatcher loop: drain → batch → execute → reply.
fn dispatcher(rt: Runtime, bucket: Bucket, ch: Channel<Request>,
              metrics: Arc<ServeMetrics>, params: Arc<Vec<f32>>,
              policy: BatchPolicy, seed: i32) {
    let exe = match rt.load(&bucket.program) {
        Ok(e) => e,
        Err(e) => {
            log::error!("dispatcher {}: {e:#}", bucket.program);
            return;
        }
    };
    let policy = BatchPolicy {
        max_batch: bucket.batch_size.min(policy.max_batch.max(1)),
        max_wait: policy.max_wait,
    };
    // Loop-invariant inputs are converted ONCE per dispatcher.  Measured
    // effect is small (~0.2% of a batch — execute dominates; §Perf), but
    // it removes a per-batch params-sized clone + conversion and keeps
    // the hot loop allocation-free on the coordinator side.
    let params_lit = match exe.prepare_one(
        0, &HostTensor::F32(params.as_ref().clone())) {
        Ok(l) => l,
        Err(e) => {
            log::error!("params literal: {e:#}");
            return;
        }
    };
    let seed_lit = match exe.prepare_one(
        exe.program.inputs.len() - 1, &HostTensor::scalar_i32(seed)) {
        Ok(l) => l,
        Err(e) => {
            log::error!("seed literal: {e:#}");
            return;
        }
    };
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    loop {
        // Wait bounded by the batcher deadline so partial batches flush.
        let item = ch.recv_timeout(batcher.next_wait(Instant::now()));
        let mut ready: Option<Vec<Request>> = None;
        match item {
            Ok(Some(req)) => {
                ready = batcher.push(req, Instant::now());
            }
            Ok(None) => {
                // closed: flush and exit
                if let Some(batch) = batcher.take() {
                    run_batch(&exe, &bucket, batch, &metrics, &params_lit,
                              &seed_lit);
                }
                return;
            }
            Err(()) => {}
        }
        if ready.is_none() {
            ready = batcher.poll_deadline(Instant::now());
        }
        if let Some(batch) = ready {
            run_batch(&exe, &bucket, batch, &metrics, &params_lit,
                      &seed_lit);
        }
    }
}

fn run_batch(exe: &crate::runtime::Executable, bucket: &Bucket,
             batch: Vec<Request>, metrics: &ServeMetrics,
             params_lit: &xla::Literal, seed_lit: &xla::Literal) {
    let b = bucket.batch_size;
    let n = bucket.seq_len;
    let d = batch.first().map(|r| r.d_feat).unwrap_or(1);
    let occupancy = batch.len();

    // pad into the static (B, N, D) input + (B,) lengths
    let mut x = vec![0f32; b * n * d];
    let mut xlen = vec![0i32; b];
    for (slot, req) in batch.iter().enumerate() {
        let copy = req.frames.len().min(n * d);
        x[slot * n * d..slot * n * d + copy]
            .copy_from_slice(&req.frames[..copy]);
        xlen[slot] = req.len as i32;
    }
    let queue_times: Vec<Duration> =
        batch.iter().map(|r| r.enqueued.elapsed()).collect();

    // only the per-batch tensors are converted here; params/seed reuse
    // the dispatcher's cached literals (§Perf)
    let result = exe
        .prepare_one(1, &HostTensor::F32(x))
        .and_then(|x_lit| {
            let xlen_lit = exe.prepare_one(2, &HostTensor::I32(xlen))?;
            exe.run_literals_borrowed(&[params_lit, &x_lit, &xlen_lit,
                                        seed_lit])
        });
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(occupancy as u64, Ordering::Relaxed);

    match result {
        Ok(mut out) => {
            let logits = out.remove(0).into_f32().unwrap_or_default();
            let vocab = logits.len() / (b * n);
            for (slot, req) in batch.into_iter().enumerate() {
                let rows =
                    logits[slot * n * vocab..(slot + 1) * n * vocab].to_vec();
                let total = req.enqueued.elapsed();
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                crate::exec::lock_unpoisoned(&metrics.latency).record(total);
                let _ = req.reply.send(Response {
                    id: req.id,
                    logits: rows,
                    vocab,
                    valid_len: req.len,
                    queue_time: queue_times[slot],
                    total_time: total,
                    batch_occupancy: occupancy,
                });
            }
        }
        Err(e) => {
            log::error!("batch execution failed: {e:#}");
            // drop; senders see a closed channel
        }
    }
}

// ---------------------------------------------------------------------------
// native batched multi-head attention engine
// ---------------------------------------------------------------------------

/// Static (H, N, Dk, Dv) shape one native engine serves (its "bucket").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub heads: usize,
    pub seq_len: usize,
    pub dk: usize,
    pub dv: usize,
}

impl AttnShape {
    pub fn qk_len(&self) -> usize {
        self.heads * self.seq_len * self.dk
    }
    pub fn v_len(&self) -> usize {
        self.heads * self.seq_len * self.dv
    }
}

/// One multi-head attention request: `q`/`k` are (H, N, Dk) and `v` is
/// (H, N, Dv), flattened row-major.
pub struct AttnRequest {
    pub id: u64,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<AttnResponse>,
}

/// Per-request result: the (H, N, Dv) output, flattened row-major.
#[derive(Debug, Clone)]
pub struct AttnResponse {
    pub id: u64,
    pub out: Vec<f32>,
    pub queue_time: Duration,
    pub total_time: Duration,
    pub batch_occupancy: usize,
}

#[derive(Debug, Clone)]
pub struct NativeAttnOptions {
    pub policy: BatchPolicy,
    pub queue_capacity: usize,
    /// Exec-pool workers.  `solve_batch` splits them between the
    /// (batch × head) slice axis and intra-slice tiled compute — a
    /// lone long-N request still uses the whole budget.
    pub workers: usize,
    /// Base seed of the per-slice PRNG streams (see `prng::slice_stream`).
    pub seed: u64,
    /// Minimum output rows before an intra-slice op goes parallel
    /// (0 = `exec::DEFAULT_PAR_ROWS`).  Lower it for long-N /
    /// small-batch buckets where single-request latency matters most.
    pub par_rows: usize,
}

impl Default for NativeAttnOptions {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            queue_capacity: 64,
            workers: WorkerPool::auto().workers(),
            seed: 0,
            par_rows: 0,
        }
    }
}

/// Serving engine for the Rust-native attention kernels: ingress queue →
/// deadline batcher → one (B, H, N, D) descriptor executed through the
/// [`NativeBackend`] seam over the exec pool → per-request replies.
/// Shares [`ServeMetrics`] with the HLO engine so benches report both
/// paths in the same terms.
///
/// One engine serves one static shape (requests arrive already at the
/// engine's exact length, so there is nothing to mask); the ragged
/// path — routing, padding and valid-length masking — is
/// [`super::ServingGateway`], a fleet of these behind the length
/// router.
///
/// ```
/// use clustered_transformers::attention::kernel_by_name;
/// use clustered_transformers::coordinator::{
///     AttnShape, NativeAttentionEngine, NativeAttnOptions,
/// };
///
/// let shape = AttnShape { heads: 1, seq_len: 8, dk: 4, dv: 4 };
/// let engine = NativeAttentionEngine::start(
///     kernel_by_name("full").unwrap(), shape,
///     NativeAttnOptions::default());
/// let rx = engine
///     .submit_blocking(vec![0.1; shape.qk_len()],
///                      vec![0.2; shape.qk_len()],
///                      vec![0.3; shape.v_len()])
///     .unwrap();
/// let resp = rx.recv().unwrap();
/// assert_eq!(resp.out.len(), shape.v_len());
/// engine.shutdown();
/// ```
pub struct NativeAttentionEngine {
    shape: AttnShape,
    ingress: Channel<AttnRequest>,
    pub metrics: Arc<ServeMetrics>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl NativeAttentionEngine {
    // construction-time spawn failure is unrecoverable (see ct-lint allow below)
    #[allow(clippy::expect_used)]
    pub fn start(kernel: Box<dyn AttentionKernel>, shape: AttnShape,
                 opts: NativeAttnOptions) -> Self {
        let ingress: Channel<AttnRequest> =
            Channel::bounded(opts.queue_capacity.max(1));
        let metrics = Arc::new(ServeMetrics::default());
        let ch = ingress.clone();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name(format!("ct-native-attn-{}", shape.seq_len))
            .spawn(move || native_dispatcher(kernel, shape, ch, m, opts))
            // ct-lint: allow(panic-expect, reason = "construction-time thread spawn: no engine exists to degrade yet, and OS spawn failure here is unrecoverable")
            .expect("spawn native attention dispatcher");
        Self {
            shape,
            ingress,
            metrics,
            worker: Some(worker),
            next_id: AtomicU64::new(0),
        }
    }

    pub fn shape(&self) -> AttnShape {
        self.shape
    }

    fn make_request(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>)
                    -> Result<(AttnRequest, mpsc::Receiver<AttnResponse>)> {
        if q.len() != self.shape.qk_len() || k.len() != self.shape.qk_len()
            || v.len() != self.shape.v_len()
        {
            return Err(anyhow!(
                "attention request shape mismatch: got q={} k={} v={}, \
                 want q=k={} v={} for {:?}",
                q.len(), k.len(), v.len(), self.shape.qk_len(),
                self.shape.v_len(), self.shape));
        }
        let (tx, rx) = mpsc::channel();
        let req = AttnRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            q,
            k,
            v,
            enqueued: Instant::now(),
            reply: tx,
        };
        Ok((req, rx))
    }

    /// Fail-fast submit (backpressure surfaces as an error).
    pub fn submit(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>)
                  -> Result<mpsc::Receiver<AttnResponse>> {
        let (req, rx) = self.make_request(q, k, v)?;
        self.ingress.try_send(req).map_err(|_| {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow!("native attention queue full (backpressure)")
        })?;
        Ok(rx)
    }

    /// Blocking submit (waits out backpressure instead of failing).
    pub fn submit_blocking(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>)
                           -> Result<mpsc::Receiver<AttnResponse>> {
        let (req, rx) = self.make_request(q, k, v)?;
        self.ingress
            .send(req)
            .map_err(|_| anyhow!("native attention engine shut down"))?;
        Ok(rx)
    }

    pub fn shutdown(mut self) {
        self.ingress.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn native_dispatcher(kernel: Box<dyn AttentionKernel>, shape: AttnShape,
                     ch: Channel<AttnRequest>, metrics: Arc<ServeMetrics>,
                     opts: NativeAttnOptions) {
    // the engine drives its kernel through the backend seam, like the
    // gateway dispatchers — one descriptor per flush
    let backend = NativeBackend::new(kernel);
    let pool = ExecCtx::with_par_rows(WorkerPool::new(opts.workers),
                                      opts.par_rows);
    let mut batcher: Batcher<AttnRequest> = Batcher::new(opts.policy);
    loop {
        let item = ch.recv_timeout(batcher.next_wait(Instant::now()));
        let mut ready: Option<Vec<AttnRequest>> = None;
        match item {
            Ok(Some(req)) => {
                ready = batcher.push(req, Instant::now());
            }
            Ok(None) => {
                if let Some(batch) = batcher.take() {
                    run_native_batch(&backend, shape, batch, &metrics,
                                     &pool, opts.seed);
                }
                return;
            }
            Err(()) => {}
        }
        if ready.is_none() {
            ready = batcher.poll_deadline(Instant::now());
        }
        if let Some(batch) = ready {
            run_native_batch(&backend, shape, batch, &metrics, &pool,
                             opts.seed);
        }
    }
}

fn run_native_batch(backend: &dyn AttentionBackend, shape: AttnShape,
                    batch: Vec<AttnRequest>, metrics: &ServeMetrics,
                    pool: &ExecCtx, seed: u64) {
    let b = batch.len();
    let occupancy = b;
    // assemble (B, H, N, D): request order is batch order, each request
    // already holds its H stacked slices contiguously
    let mut qd = Vec::with_capacity(b * shape.qk_len());
    let mut kd = Vec::with_capacity(b * shape.qk_len());
    let mut vd = Vec::with_capacity(b * shape.v_len());
    for req in &batch {
        qd.extend_from_slice(&req.q);
        kd.extend_from_slice(&req.k);
        vd.extend_from_slice(&req.v);
    }
    let q = BatchMatrix::from_vec(b, shape.heads, shape.seq_len, shape.dk,
                                  qd);
    let k = BatchMatrix::from_vec(b, shape.heads, shape.seq_len, shape.dk,
                                  kd);
    let v = BatchMatrix::from_vec(b, shape.heads, shape.seq_len, shape.dv,
                                  vd);
    let queue_times: Vec<Duration> =
        batch.iter().map(|r| r.enqueued.elapsed()).collect();

    // dense descriptor: engine requests arrive at the exact shape, so
    // there are no lens to mask
    let out = backend.execute(&AttnBatch::new(&q, &k, &v, seed), pool);

    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(occupancy as u64, Ordering::Relaxed);

    let per_req = shape.v_len();
    for (slot, req) in batch.into_iter().enumerate() {
        let rows = out.data[slot * per_req..(slot + 1) * per_req].to_vec();
        let total = req.enqueued.elapsed();
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        crate::exec::lock_unpoisoned(&metrics.latency).record(total);
        let _ = req.reply.send(AttnResponse {
            id: req.id,
            out: rows,
            queue_time: queue_times[slot],
            total_time: total,
            batch_occupancy: occupancy,
        });
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::attention::{kernel_for, solve_batch_seq, Variant};
    use crate::prng::Xoshiro256;

    const SHAPE: AttnShape =
        AttnShape { heads: 2, seq_len: 32, dk: 8, dv: 8 };

    fn variant() -> Variant {
        Variant::Clustered { clusters: 4, bits: 31, iters: 5 }
    }

    fn request_tensors(n_req: usize, seed: u64)
                       -> (BatchMatrix, BatchMatrix, BatchMatrix) {
        let mut rng = Xoshiro256::new(seed);
        let q = BatchMatrix::randn(n_req, SHAPE.heads, SHAPE.seq_len,
                                   SHAPE.dk, &mut rng);
        let k = BatchMatrix::randn(n_req, SHAPE.heads, SHAPE.seq_len,
                                   SHAPE.dk, &mut rng);
        let v = BatchMatrix::randn(n_req, SHAPE.heads, SHAPE.seq_len,
                                   SHAPE.dv, &mut rng);
        (q, k, v)
    }

    /// (H, N, D) block of request `r` from a (R, H, N, D) tensor.
    fn req_block(t: &BatchMatrix, r: usize) -> Vec<f32> {
        let per = t.heads * t.rows * t.cols;
        t.data[r * per..(r + 1) * per].to_vec()
    }

    #[test]
    fn native_engine_matches_sequential_run_batch_bit_for_bit() {
        let (q, k, v) = request_tensors(2, 31);
        let engine = NativeAttentionEngine::start(
            kernel_for(&variant()),
            SHAPE,
            NativeAttnOptions {
                policy: BatchPolicy {
                    max_batch: 2,
                    // generous deadline: the batch must form on the size
                    // trigger even if CI stalls between the two submits
                    max_wait: Duration::from_secs(10),
                },
                queue_capacity: 8,
                workers: 4,
                seed: 17,
                par_rows: 0,
            },
        );
        let rx0 = engine
            .submit_blocking(req_block(&q, 0), req_block(&k, 0),
                             req_block(&v, 0))
            .unwrap();
        let rx1 = engine
            .submit_blocking(req_block(&q, 1), req_block(&k, 1),
                             req_block(&v, 1))
            .unwrap();
        let r0 = rx0.recv_timeout(Duration::from_secs(30)).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r0.batch_occupancy, 2, "requests were not co-batched");

        // reference: the explicit sequential loop over the same batch
        let want = solve_batch_seq(kernel_for(&variant()).as_ref(),
                                   &AttnBatch::new(&q, &k, &v, 17));
        let per = SHAPE.v_len();
        assert_eq!(r0.out.len(), per);
        let same = |got: &[f32], want: &[f32]| {
            got.iter().zip(want)
                .all(|(a, b)| a.to_bits() == b.to_bits())
        };
        assert!(same(&r0.out, &want.data[..per]));
        assert!(same(&r1.out, &want.data[per..2 * per]));

        assert_eq!(engine.metrics.completed.load(Ordering::Relaxed), 2);
        assert!((engine.metrics.occupancy() - 2.0).abs() < 1e-9);
        engine.shutdown();
    }

    #[test]
    fn native_engine_rejects_malformed_shapes() {
        let engine = NativeAttentionEngine::start(
            kernel_for(&variant()), SHAPE, NativeAttnOptions::default());
        let err = engine
            .submit(vec![0.0; 3], vec![0.0; SHAPE.qk_len()],
                    vec![0.0; SHAPE.v_len()])
            .err()
            .expect("short q must be rejected");
        assert!(format!("{err}").contains("shape mismatch"));
        assert_eq!(engine.shape(), SHAPE);
        engine.shutdown();
    }
}

