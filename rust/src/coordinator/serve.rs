//! The inference engine: router → per-bucket dynamic batcher → worker
//! threads executing compiled forward programs → responses.
//!
//! One dispatcher thread per bucket owns that bucket's batcher and
//! executable; the shared ingress queue provides backpressure (bounded —
//! `submit` blocks or fails fast when the system is saturated).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::exec::Channel;
use crate::metrics::LatencyHistogram;
use crate::runtime::{HostTensor, Runtime};

use super::batcher::{BatchPolicy, Batcher};
use super::router::{Bucket, Router};

/// An inference request: `frames` is (len × d_feat) row-major features
/// (ASR) — the engine pads it into the bucket's static shape.
pub struct Request {
    pub id: u64,
    pub frames: Vec<f32>,
    pub len: usize,
    pub d_feat: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// Per-request result: the logits rows for the valid frames.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub vocab: usize,
    pub valid_len: usize,
    pub queue_time: Duration,
    pub total_time: Duration,
    pub batch_occupancy: usize,
}

#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub policy: BatchPolicy,
    pub queue_capacity: usize,
    pub params_seed: i32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), queue_capacity: 64,
               params_seed: 0 }
    }
}

/// Aggregated serving metrics.
#[derive(Default)]
pub struct ServeMetrics {
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub latency: Mutex<LatencyHistogram>,
}

impl ServeMetrics {
    pub fn occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }
}

pub struct InferenceEngine {
    router: Router,
    ingress: Vec<Channel<Request>>, // one per bucket
    pub metrics: Arc<ServeMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl InferenceEngine {
    /// Build from forward programs (one per bucket) and model params.
    ///
    /// The `xla` crate's PJRT client is `Rc`-based (not `Send`), so each
    /// dispatcher thread opens its *own* `Runtime` on `artifacts_dir` and
    /// compiles its bucket's executable locally — no client ever crosses
    /// a thread boundary.
    pub fn start(rt: &Runtime, programs: &[String], params: Vec<f32>,
                 opts: ServeOptions) -> Result<Self> {
        let mut buckets = Vec::new();
        for name in programs {
            let p = rt.program(name)?;
            buckets.push(Bucket {
                program: name.clone(),
                seq_len: p.seq_len(),
                batch_size: p.batch_size(),
            });
        }
        let artifacts_dir = rt.dir.clone();
        let router = Router::new(buckets)?;
        let metrics = Arc::new(ServeMetrics::default());
        let params = Arc::new(params);

        let mut ingress = Vec::new();
        let mut workers = Vec::new();
        for bucket in router.buckets() {
            let ch: Channel<Request> = Channel::bounded(opts.queue_capacity);
            ingress.push(ch.clone());
            let dir = artifacts_dir.clone();
            let bucket = bucket.clone();
            let metrics = metrics.clone();
            let params = params.clone();
            let policy = opts.policy;
            let seed = opts.params_seed;
            workers.push(std::thread::Builder::new()
                .name(format!("ct-dispatch-{}", bucket.seq_len))
                .spawn(move || {
                    let rt = match Runtime::open(&dir) {
                        Ok(rt) => rt,
                        Err(e) => {
                            log::error!("dispatcher runtime: {e:#}");
                            return;
                        }
                    };
                    dispatcher(rt, bucket, ch, metrics, params, policy, seed)
                })?);
        }
        Ok(Self { router, ingress, metrics, workers,
                  next_id: AtomicU64::new(0) })
    }

    /// Submit a request; the response arrives on the returned receiver.
    /// Fails fast when the request is too long or the queue is full
    /// (backpressure surfaces to the caller, as a real router would 429).
    pub fn submit(&self, frames: Vec<f32>, len: usize, d_feat: usize)
                  -> Result<mpsc::Receiver<Response>> {
        let idx = self
            .router
            .route_index(len)
            .ok_or_else(|| anyhow!("request of length {len} exceeds every \
                                    bucket"))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            frames,
            len,
            d_feat,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.ingress[idx].try_send(req).map_err(|_| {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow!("bucket {idx} queue full (backpressure)")
        })?;
        Ok(rx)
    }

    /// Blocking submit (waits out backpressure instead of failing).
    pub fn submit_blocking(&self, frames: Vec<f32>, len: usize,
                           d_feat: usize) -> Result<mpsc::Receiver<Response>> {
        let idx = self
            .router
            .route_index(len)
            .ok_or_else(|| anyhow!("request too long"))?;
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            frames,
            len,
            d_feat,
            enqueued: Instant::now(),
            reply: tx,
        };
        self.ingress[idx]
            .send(req)
            .map_err(|_| anyhow!("engine shut down"))?;
        Ok(rx)
    }

    pub fn shutdown(self) {
        for ch in &self.ingress {
            ch.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Per-bucket dispatcher loop: drain → batch → execute → reply.
fn dispatcher(rt: Runtime, bucket: Bucket, ch: Channel<Request>,
              metrics: Arc<ServeMetrics>, params: Arc<Vec<f32>>,
              policy: BatchPolicy, seed: i32) {
    let exe = match rt.load(&bucket.program) {
        Ok(e) => e,
        Err(e) => {
            log::error!("dispatcher {}: {e:#}", bucket.program);
            return;
        }
    };
    let policy = BatchPolicy {
        max_batch: bucket.batch_size.min(policy.max_batch.max(1)),
        max_wait: policy.max_wait,
    };
    // Loop-invariant inputs are converted ONCE per dispatcher.  Measured
    // effect is small (~0.2% of a batch — execute dominates; §Perf), but
    // it removes a per-batch params-sized clone + conversion and keeps
    // the hot loop allocation-free on the coordinator side.
    let params_lit = match exe.prepare_one(
        0, &HostTensor::F32(params.as_ref().clone())) {
        Ok(l) => l,
        Err(e) => {
            log::error!("params literal: {e:#}");
            return;
        }
    };
    let seed_lit = match exe.prepare_one(
        exe.program.inputs.len() - 1, &HostTensor::scalar_i32(seed)) {
        Ok(l) => l,
        Err(e) => {
            log::error!("seed literal: {e:#}");
            return;
        }
    };
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    loop {
        // Wait bounded by the batcher deadline so partial batches flush.
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        let item = ch.recv_timeout(wait.max(Duration::from_micros(100)));
        let mut ready: Option<Vec<Request>> = None;
        match item {
            Ok(Some(req)) => {
                ready = batcher.push(req, Instant::now());
            }
            Ok(None) => {
                // closed: flush and exit
                if let Some(batch) = batcher.take() {
                    run_batch(&exe, &bucket, batch, &metrics, &params_lit,
                              &seed_lit);
                }
                return;
            }
            Err(()) => {}
        }
        if ready.is_none() {
            ready = batcher.poll_deadline(Instant::now());
        }
        if let Some(batch) = ready {
            run_batch(&exe, &bucket, batch, &metrics, &params_lit,
                      &seed_lit);
        }
    }
}

fn run_batch(exe: &crate::runtime::Executable, bucket: &Bucket,
             batch: Vec<Request>, metrics: &ServeMetrics,
             params_lit: &xla::Literal, seed_lit: &xla::Literal) {
    let b = bucket.batch_size;
    let n = bucket.seq_len;
    let d = batch.first().map(|r| r.d_feat).unwrap_or(1);
    let occupancy = batch.len();

    // pad into the static (B, N, D) input + (B,) lengths
    let mut x = vec![0f32; b * n * d];
    let mut xlen = vec![0i32; b];
    for (slot, req) in batch.iter().enumerate() {
        let copy = req.frames.len().min(n * d);
        x[slot * n * d..slot * n * d + copy]
            .copy_from_slice(&req.frames[..copy]);
        xlen[slot] = req.len as i32;
    }
    let queue_times: Vec<Duration> =
        batch.iter().map(|r| r.enqueued.elapsed()).collect();

    // only the per-batch tensors are converted here; params/seed reuse
    // the dispatcher's cached literals (§Perf)
    let result = exe
        .prepare_one(1, &HostTensor::F32(x))
        .and_then(|x_lit| {
            let xlen_lit = exe.prepare_one(2, &HostTensor::I32(xlen))?;
            exe.run_literals_borrowed(&[params_lit, &x_lit, &xlen_lit,
                                        seed_lit])
        });
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(occupancy as u64, Ordering::Relaxed);

    match result {
        Ok(mut out) => {
            let logits = out.remove(0).into_f32().unwrap_or_default();
            let vocab = logits.len() / (b * n);
            for (slot, req) in batch.into_iter().enumerate() {
                let rows =
                    logits[slot * n * vocab..(slot + 1) * n * vocab].to_vec();
                let total = req.enqueued.elapsed();
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.latency.lock().unwrap().record(total);
                let _ = req.reply.send(Response {
                    id: req.id,
                    logits: rows,
                    vocab,
                    valid_len: req.len,
                    queue_time: queue_times[slot],
                    total_time: total,
                    batch_occupancy: occupancy,
                });
            }
        }
        Err(e) => {
            log::error!("batch execution failed: {e:#}");
            // drop; senders see a closed channel
        }
    }
}
