//! Bridges the synthetic corpora (`data/`) to AOT program input layouts.
//!
//! A [`DataFeed`] produces, for a given (split, batch index), the batch
//! tensors in the exact order the train/forward programs declare after
//! the state inputs (`params, adam_m, adam_v, step, seed`).

use anyhow::{bail, Result};

use crate::data::{asr, copy_task, glue, Split};
use crate::runtime::{HostTensor, Program};

/// Which corpus feeds a model, derived from the model name prefix.
#[derive(Debug, Clone)]
pub enum DataFeed {
    Copy(copy_task::CopyTask),
    Asr(std::sync::Arc<asr::AsrCorpus>),
    GlueCls { task: glue::GlueTask, seed: u64 },
    GlueSpan { seed: u64 },
}

impl DataFeed {
    /// Infer the right corpus from a manifest program.
    pub fn for_program(p: &Program, seed: u64) -> Result<DataFeed> {
        let name = p.model_name();
        let n = p.seq_len();
        if name.starts_with("copy-") || name.starts_with("layer-") {
            Ok(DataFeed::Copy(copy_task::CopyTask::new(n, seed)))
        } else if name.starts_with("wsj-") {
            Ok(DataFeed::Asr(std::sync::Arc::new(asr::AsrCorpus::new(
                asr::AsrSpec::wsj(seed)))))
        } else if name.starts_with("swb-") {
            Ok(DataFeed::Asr(std::sync::Arc::new(asr::AsrCorpus::new(
                asr::AsrSpec::swb(seed)))))
        } else if let Some(rest) = name.strip_prefix("glue-") {
            let task_name = rest.split('-').next().unwrap_or("");
            let task = glue::GlueTask::from_name(task_name)
                .ok_or_else(|| anyhow::anyhow!("unknown glue task \
                                                {task_name}"))?;
            if task == glue::GlueTask::Squad {
                Ok(DataFeed::GlueSpan { seed })
            } else {
                Ok(DataFeed::GlueCls { task, seed })
            }
        } else {
            bail!("cannot infer datafeed for model {name:?}")
        }
    }

    /// Batch tensors in `batch_specs` order (see programs.py docstring).
    pub fn batch(&self, split: Split, index: u64, batch: usize)
                 -> Vec<HostTensor> {
        match self {
            DataFeed::Copy(task) => {
                let b = task.batch(split, index, batch);
                vec![HostTensor::I32(b.x), HostTensor::I32(b.y),
                     HostTensor::F32(b.w)]
            }
            DataFeed::Asr(corpus) => {
                let b = corpus.batch(split, index, batch);
                vec![HostTensor::F32(b.x), HostTensor::I32(b.xlen),
                     HostTensor::I32(b.y), HostTensor::I32(b.ylen)]
            }
            DataFeed::GlueCls { task, seed } => {
                let b = glue::cls_batch(*task, *seed, split, index, batch);
                vec![HostTensor::I32(b.x), HostTensor::F32(b.mask),
                     HostTensor::I32(b.y)]
            }
            DataFeed::GlueSpan { seed } => {
                let b = glue::span_batch(*seed, split, index, batch);
                vec![HostTensor::I32(b.x), HostTensor::F32(b.mask),
                     HostTensor::I32(b.ystart), HostTensor::I32(b.yend)]
            }
        }
    }

    /// Forward-program inputs (x [+ xlen/mask]) for the same batch, i.e.
    /// the batch tensors minus the targets.
    pub fn forward_inputs(&self, split: Split, index: u64, batch: usize)
                          -> Vec<HostTensor> {
        let mut b = self.batch(split, index, batch);
        match self {
            DataFeed::Copy(_) => b.truncate(1),       // x
            DataFeed::Asr(_) => b.truncate(2),        // x, xlen
            DataFeed::GlueCls { .. } => b.truncate(2), // x, mask
            DataFeed::GlueSpan { .. } => b.truncate(2),
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;
    use crate::runtime::{Dtype, TensorSpec};

    fn fake_program(model: &str, n: usize, b: usize) -> Program {
        Program {
            name: format!("{model}.train"),
            kind: "train".into(),
            file: String::new(),
            inputs: vec![TensorSpec { name: "params".into(),
                                      shape: vec![8], dtype: Dtype::F32 }],
            outputs: vec![],
            config: jsonio::parse(&format!(
                r#"{{"name":"{model}","seq_len":{n},"batch_size":{b}}}"#))
                .unwrap(),
            param_count: 8,
        }
    }

    #[test]
    fn infers_feed_from_model_name() {
        let p = fake_program("copy-n64-full", 64, 16);
        assert!(matches!(DataFeed::for_program(&p, 0).unwrap(),
                         DataFeed::Copy(_)));
        let p = fake_program("wsj-l6-full", 256, 4);
        assert!(matches!(DataFeed::for_program(&p, 0).unwrap(),
                         DataFeed::Asr(_)));
        let p = fake_program("glue-squad-full", 192, 8);
        assert!(matches!(DataFeed::for_program(&p, 0).unwrap(),
                         DataFeed::GlueSpan { .. }));
        let p = fake_program("glue-rte-full", 128, 8);
        assert!(matches!(DataFeed::for_program(&p, 0).unwrap(),
                         DataFeed::GlueCls { .. }));
        let p = fake_program("mystery", 16, 1);
        assert!(DataFeed::for_program(&p, 0).is_err());
    }

    #[test]
    fn copy_feed_shapes() {
        let p = fake_program("copy-n32-full", 32, 4);
        let feed = DataFeed::for_program(&p, 1).unwrap();
        let b = feed.batch(Split::Train, 0, 4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].len(), 4 * 32);
        let f = feed.forward_inputs(Split::Train, 0, 4);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn asr_feed_shapes() {
        let p = fake_program("wsj-l6-full", 256, 2);
        let feed = DataFeed::for_program(&p, 1).unwrap();
        let b = feed.batch(Split::Valid, 3, 2);
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].len(), 2 * 256 * 40);
        assert_eq!(b[1].len(), 2);
    }
}
