//! ct-contract: panic-free
//!
//! Deadline-based dynamic batcher.
//!
//! Collects requests until either the bucket's batch size is full or the
//! oldest request has waited `max_wait` — the classic throughput/latency
//! dial the serving benches sweep.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(5) }
    }
}

/// Accumulates items into deadline-bounded batches.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, pending: Vec::new(), oldest: None }
    }

    /// Add an item; returns a full batch if the size trigger fired.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(item);
        if self.pending.len() >= self.policy.max_batch {
            self.take()
        } else {
            None
        }
    }

    /// Returns a (possibly partial) batch if the deadline expired.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Vec<T>> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty()
                && now.duration_since(t0) >= self.policy.max_wait =>
            {
                self.take()
            }
            _ => None,
        }
    }

    /// How long until the current deadline fires (None when empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.policy.max_wait.saturating_sub(elapsed)
        })
    }

    /// How long a dispatcher should block waiting for the next ingress
    /// item: the time to the current deadline (floored at 100µs so a
    /// nearly-expired deadline still yields the CPU), or a 50ms idle
    /// poll when nothing is pending.
    pub fn next_wait(&self, now: Instant) -> Duration {
        self.time_to_deadline(now)
            .unwrap_or(Duration::from_millis(50))
            .max(Duration::from_micros(100))
    }

    /// Flush whatever is pending.
    pub fn take(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            return None;
        }
        self.oldest = None;
        Some(std::mem::take(&mut self.pending))
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn size_trigger_fires_exactly_at_max_batch() {
        let mut b = Batcher::new(policy(3, 1000));
        let now = Instant::now();
        assert!(b.push(1, now).is_none());
        assert!(b.push(2, now).is_none());
        let batch = b.push(3, now).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_trigger_fires_on_oldest() {
        let mut b = Batcher::new(policy(10, 5));
        let t0 = Instant::now();
        b.push(1, t0);
        assert!(b.poll_deadline(t0).is_none());
        assert!(b.poll_deadline(t0 + Duration::from_millis(2)).is_none());
        let batch = b.poll_deadline(t0 + Duration::from_millis(6)).unwrap();
        assert_eq!(batch, vec![1]);
    }

    #[test]
    fn deadline_resets_after_flush() {
        let mut b = Batcher::new(policy(10, 5));
        let t0 = Instant::now();
        b.push(1, t0);
        b.take();
        b.push(2, t0 + Duration::from_millis(10));
        // new oldest is t0+10ms, so nothing fires at t0+12ms
        assert!(b
            .poll_deadline(t0 + Duration::from_millis(12))
            .is_none());
        assert!(b
            .poll_deadline(t0 + Duration::from_millis(16))
            .is_some());
    }

    #[test]
    fn next_wait_is_deadline_bounded_and_floored() {
        let mut b = Batcher::new(policy(10, 8));
        let t0 = Instant::now();
        // empty: idle poll
        assert_eq!(b.next_wait(t0), Duration::from_millis(50));
        b.push(1, t0);
        // pending: bounded by the remaining deadline
        let w = b.next_wait(t0 + Duration::from_millis(3));
        assert!(w <= Duration::from_millis(5));
        // expired deadline: floored, never zero-spin
        let w = b.next_wait(t0 + Duration::from_millis(20));
        assert_eq!(w, Duration::from_micros(100));
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b = Batcher::new(policy(10, 8));
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push(1, t0);
        let d = b.time_to_deadline(t0 + Duration::from_millis(3)).unwrap();
        assert!(d <= Duration::from_millis(5));
    }
}
