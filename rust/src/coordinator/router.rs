//! Length-bucket router: HLO executables have static shapes, so requests
//! are routed to the smallest compiled bucket that fits, then padded.

use anyhow::{bail, Result};

/// One serving bucket: a compiled forward program with static (B, N).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    pub program: String,
    pub seq_len: usize,
    pub batch_size: usize,
}

/// Routes requests by sequence length.
#[derive(Debug, Clone, Default)]
pub struct Router {
    buckets: Vec<Bucket>, // sorted by seq_len ascending
}

impl Router {
    pub fn new(mut buckets: Vec<Bucket>) -> Result<Self> {
        if buckets.is_empty() {
            bail!("router needs at least one bucket");
        }
        buckets.sort_by_key(|b| b.seq_len);
        Ok(Self { buckets })
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Smallest bucket with seq_len >= len; None if the request is too
    /// long for every compiled program (caller rejects with backpressure).
    pub fn route(&self, len: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.seq_len >= len)
    }

    /// Index variant of [`route`].
    pub fn route_index(&self, len: usize) -> Option<usize> {
        self.buckets.iter().position(|b| b.seq_len >= len)
    }

    /// Padding waste fraction for a request of `len` in its bucket.
    pub fn padding_waste(&self, len: usize) -> Option<f64> {
        self.route(len)
            .map(|b| 1.0 - len as f64 / b.seq_len as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            Bucket { program: "b256".into(), seq_len: 256, batch_size: 4 },
            Bucket { program: "b64".into(), seq_len: 64, batch_size: 8 },
            Bucket { program: "b128".into(), seq_len: 128, batch_size: 8 },
        ])
        .unwrap()
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let r = router();
        assert_eq!(r.route(1).unwrap().seq_len, 64);
        assert_eq!(r.route(64).unwrap().seq_len, 64);
        assert_eq!(r.route(65).unwrap().seq_len, 128);
        assert_eq!(r.route(200).unwrap().seq_len, 256);
        assert!(r.route(257).is_none());
    }

    #[test]
    fn padding_waste_monotone_within_bucket() {
        let r = router();
        assert!(r.padding_waste(64).unwrap() < 1e-9);
        let w65 = r.padding_waste(65).unwrap();
        let w128 = r.padding_waste(128).unwrap();
        assert!(w65 > w128);
    }

    #[test]
    fn empty_router_rejected() {
        assert!(Router::new(vec![]).is_err());
    }
}
