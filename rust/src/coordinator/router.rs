//! ct-contract: panic-free
//!
//! Length-bucket router: serving programs have static shapes, so requests
//! are routed to the smallest bucket that fits, then padded.
//!
//! A [`Bucket`] describes one static-shape serving unit.  Two flavors
//! share the struct: compiled-HLO buckets carry a `program` name (the
//! [`super::InferenceEngine`] path), native buckets carry an attention
//! `kernel` registry name plus pad-to length and batch size (the
//! [`super::ServingGateway`] path).  The [`Router`] itself is agnostic —
//! it only orders buckets by `seq_len` and picks the tightest fit.

use anyhow::{bail, Result};

/// One serving bucket: a static (B, N) execution shape.
///
/// `program` names a compiled forward program (HLO buckets) and `kernel`
/// names a native attention kernel in the registry (gateway buckets);
/// exactly one of the two is non-empty in practice.  `seq_len` is the
/// pad-to length and `batch_size` the maximum co-batched requests.
///
/// ```
/// use clustered_transformers::coordinator::Bucket;
///
/// let b = Bucket::native("i-clustered-100", 256, 8);
/// assert_eq!((b.seq_len, b.batch_size), (256, 8));
/// assert_eq!(b.kernel, "i-clustered-100");
/// assert!(b.program.is_empty());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Compiled forward program name (empty for native buckets).
    pub program: String,
    /// Static sequence length requests are padded to.
    pub seq_len: usize,
    /// Maximum requests co-batched into one execution.
    pub batch_size: usize,
    /// Native kernel registry name, e.g. `"i-clustered-100"` (empty for
    /// compiled-HLO buckets).
    pub kernel: String,
}

impl Bucket {
    /// Compiled-HLO bucket (the [`super::InferenceEngine`] path).
    pub fn hlo(program: impl Into<String>, seq_len: usize,
               batch_size: usize) -> Self {
        Self { program: program.into(), seq_len, batch_size,
               kernel: String::new() }
    }

    /// Native-kernel bucket (the [`super::ServingGateway`] path).
    pub fn native(kernel: impl Into<String>, seq_len: usize,
                  batch_size: usize) -> Self {
        Self { program: String::new(), seq_len, batch_size,
               kernel: kernel.into() }
    }
}

/// Routes requests by sequence length.
///
/// ```
/// use clustered_transformers::coordinator::{Bucket, Router};
///
/// let r = Router::new(vec![
///     Bucket::native("full", 128, 4),
///     Bucket::native("full", 64, 8),
/// ]).unwrap();
/// assert_eq!(r.route(64).unwrap().seq_len, 64);  // exact fit
/// assert_eq!(r.route(65).unwrap().seq_len, 128); // next bucket up
/// assert!(r.route(129).is_none());               // too long: reject
/// ```
#[derive(Debug, Clone, Default)]
pub struct Router {
    buckets: Vec<Bucket>, // sorted by seq_len ascending
}

impl Router {
    pub fn new(mut buckets: Vec<Bucket>) -> Result<Self> {
        if buckets.is_empty() {
            bail!("router needs at least one bucket");
        }
        buckets.sort_by_key(|b| b.seq_len);
        Ok(Self { buckets })
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// Longest request any bucket can hold.
    pub fn max_len(&self) -> usize {
        self.buckets.last().map(|b| b.seq_len).unwrap_or(0)
    }

    /// Smallest bucket with seq_len >= len; None if the request is too
    /// long for every compiled program (caller rejects with backpressure).
    pub fn route(&self, len: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.seq_len >= len)
    }

    /// Index variant of [`Router::route`].
    pub fn route_index(&self, len: usize) -> Option<usize> {
        self.buckets.iter().position(|b| b.seq_len >= len)
    }

    /// Every bucket index that can hold `len`, tightest fit first.
    ///
    /// This is the route-up order: when the tight bucket's queue is full,
    /// an admission controller can spill the request into the next larger
    /// bucket (trading padding waste for acceptance).  Empty when `len`
    /// exceeds every bucket.
    pub fn route_candidates(&self, len: usize)
                            -> impl Iterator<Item = usize> + '_ {
        let start = self
            .route_index(len)
            .unwrap_or(self.buckets.len());
        start..self.buckets.len()
    }

    /// Padding waste fraction for a request of `len` in its bucket.
    ///
    /// This is the *memory* waste of the static batch buffers.  With
    /// the gateway's valid-length masking on (the default), the padded
    /// rows are never computed — see
    /// `metrics::PaddingWaste::compute_saved` — so routing a request up
    /// a bucket costs buffer space, not kernel time.
    pub fn padding_waste(&self, len: usize) -> Option<f64> {
        self.route(len)
            .map(|b| 1.0 - len as f64 / b.seq_len as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            Bucket::hlo("b256", 256, 4),
            Bucket::hlo("b64", 64, 8),
            Bucket::hlo("b128", 128, 8),
        ])
        .unwrap()
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let r = router();
        assert_eq!(r.route(1).unwrap().seq_len, 64);
        assert_eq!(r.route(64).unwrap().seq_len, 64);
        assert_eq!(r.route(65).unwrap().seq_len, 128);
        assert_eq!(r.route(200).unwrap().seq_len, 256);
        assert!(r.route(257).is_none());
    }

    #[test]
    fn zero_length_routes_to_smallest_bucket() {
        let r = router();
        assert_eq!(r.route(0).unwrap().seq_len, 64);
        assert_eq!(r.route_index(0), Some(0));
        assert!((r.padding_waste(0).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn exact_match_has_zero_waste() {
        let r = router();
        for (len, idx) in [(64, 0), (128, 1), (256, 2)] {
            assert_eq!(r.route_index(len), Some(idx));
            assert!(r.padding_waste(len).unwrap() < 1e-9);
        }
    }

    #[test]
    fn over_max_is_rejected_everywhere() {
        let r = router();
        assert!(r.route(257).is_none());
        assert_eq!(r.route_index(257), None);
        assert_eq!(r.route_candidates(257).count(), 0);
        assert!(r.padding_waste(257).is_none());
        assert_eq!(r.max_len(), 256);
    }

    #[test]
    fn route_candidates_are_tightest_first_then_up() {
        let r = router();
        assert_eq!(r.route_candidates(1).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.route_candidates(65).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(r.route_candidates(256).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn padding_waste_monotone_within_bucket() {
        let r = router();
        assert!(r.padding_waste(64).unwrap() < 1e-9);
        let w65 = r.padding_waste(65).unwrap();
        let w128 = r.padding_waste(128).unwrap();
        assert!(w65 > w128);
    }

    #[test]
    fn bucket_constructors_fill_the_right_field() {
        let h = Bucket::hlo("asr.forward", 128, 4);
        assert_eq!(h.program, "asr.forward");
        assert!(h.kernel.is_empty());
        let n = Bucket::native("clustered-100", 128, 4);
        assert_eq!(n.kernel, "clustered-100");
        assert!(n.program.is_empty());
    }

    #[test]
    fn empty_router_rejected() {
        assert!(Router::new(vec![]).is_err());
    }
}
