//! ct-contract: panic-free
//! ct-lint: allow(det-entropy, reason = "Instant::now feeds latency metrics, batch deadlines and session TTL sweeps only — never the math")
//! ct-lint: allow(panic-index, reason = "gateway indexing derives from validated bucket/shape invariants established at submit; new code should prefer get()")
//!
//! Multi-bucket native serving gateway.
//!
//! [`ServingGateway`] fronts a fleet of per-bucket native attention
//! engines behind the length [`Router`]: each [`Bucket`] carries its own
//! kernel, pad-to sequence length and batch size, owns a dispatcher
//! thread with a deadline [`Batcher`], and all buckets lease workers
//! from one [`SharedWorkerPool`] budget — live leases never sum above
//! it, and a flush queues when it is spent, so concurrent buckets can
//! never oversubscribe the host.  This is the static-shape serving
//! discipline of the compiled-HLO path ([`super::InferenceEngine`])
//! applied to the Rust-native kernels: route to the tightest bucket,
//! pad, batch, execute, return only the valid rows.
//!
//! **Valid-length masking (default on):** a flush hands the kernels an
//! `attention::AttnBatch` carrying each request's true length, so
//! padded rows are never hashed, swept or softmaxed — every response is
//! **bit-identical to the unpadded computation** of its request (the
//! masking contract, property-tested end-to-end on ragged traces).
//! Padding still costs *memory* (the batch buffers are bucket-sized);
//! it no longer costs *compute*, and [`BucketMetrics`] reports the two
//! separately.  `GatewayOptions { mask: false, … }` restores the
//! historical static-shape semantics (padded K rows participating in
//! softmax) for comparison benches.
//!
//! **Causal serving:** `GatewayOptions { causal: true, … }` marks every
//! flush descriptor autoregressive (row `i` attends keys `j <= i`);
//! start-time validation requires every bucket kernel to support it
//! (the linear family).  Causal decode sessions ride the KV cache's
//! recurrent-state path: each step updates a per-session `(S, z)`
//! accumulator and costs O(new rows · D²) regardless of history
//! length, still bit-identical to the full causal recompute.
//!
//! Admission control: `submit` fails fast with backpressure when queues
//! are full, but first *routes up* — a request that overflows its tight
//! bucket spills into the next larger bucket, trading padding waste for
//! acceptance (disable with [`GatewayOptions::route_up`]).  Requests
//! longer than every bucket are rejected outright.
//!
//! Per-bucket [`BucketMetrics`] record latency percentiles, completed /
//! rejected / routed-up counts, batch occupancy and both waste ratios
//! ([`crate::metrics::PaddingWaste`]) — the numbers the `gateway` bench
//! tabulates.
//!
//! **Determinism:** a flushed batch runs through the same
//! `AttentionKernel::solve_batch` contract as everything else — output
//! slice `s` depends only on `(inputs[s], seed, s)` — so gateway output
//! for a given batch composition is bit-identical to the sequential
//! per-slice loop over the same descriptor, regardless of pool size
//! (property-tested in `proptest/attention_props.rs`).
//!
//! Execution goes through the [`AttentionBackend`] seam
//! ([`attention::backend`](crate::attention::backend)): every bucket
//! dispatcher drives a [`CachingBackend`] wrapping a [`NativeBackend`],
//! and a compiled-HLO or sharded backend plugs in behind the same
//! descriptor.
//!
//! **Decode sessions:** [`ServingGateway::submit_session`] serves
//! autoregressive traffic.  A session submits its *full history* each
//! step (`len` grows monotonically); the gateway tracks the served
//! length, attaches a [`SessionRef`] (cache handle + span start) to the
//! flush descriptor, and the shared [`KvCache`] lets the backend solve
//! only the new rows against the cached K/V panels — the reply carries
//! just the span rows.  Sessions are *pinned* to the bucket that served
//! them and **route up** when the grown history outgrows it; the cache
//! is gateway-global, so a migrated session keeps its panels.  Session
//! PRNG streams key off the session id (`prng::session_seed`), not the
//! batch slot, so a step's bits are invariant to co-batched traffic and
//! equal the full unpadded recompute of its history
//! ([`session_reference`]) — hit or miss, property-tested per kernel
//! family.  Idle sessions expire after
//! [`GatewayOptions::session_ttl`]: a hostile TCP client that never
//! sends `"end"` cannot pin cache capacity or table entries forever
//! ([`ServingGateway::sweep_expired`]).
//!
//! **Multi-host:** with [`GatewayOptions::shards`] set, every bucket
//! dispatcher drives an `attention::ShardedBackend` over the listed
//! `ct shard-worker` hosts instead of a local [`CachingBackend`] —
//! one-shot batches split across the fleet, and decode sessions route
//! to their owning shard by consistent hash
//! ([`super::ring::HashRing`]) so cached panels stay put across steps
//! *and* bucket route-ups (every bucket's ring is built from the same
//! shard list).  Retry/backoff and degraded-mode local fallback are
//! the backend's ([`attention::sharded`](crate::attention::sharded));
//! responses stay bit-identical to single-host serving throughout.

// The panic-free serving contract, compiler-side: `ct lint` scans the
// source, clippy guards what the scanner cannot see through macros.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::attention::{AttentionBackend, AttentionKernel, AttnBatch,
                       AttnProblem, CacheQuant, CacheRef, CachingBackend,
                       KvCache, KvCacheOptions, NativeBackend,
                       SeqOutcome, SessionRef, ShardCacheStats,
                       ShardOptions, ShardedBackend};
use crate::exec::{Channel, ExecCtx, SharedWorkerPool};
use crate::metrics::{LatencyHistogram, PaddingWaste};
use crate::prng::Xoshiro256;
use crate::tensor::batch::BatchMatrix;
use crate::tensor::Matrix;

use super::batcher::{BatchPolicy, Batcher};
use super::router::{Bucket, Router};

/// The per-request tensor geometry every gateway bucket shares; only the
/// sequence length varies per bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatewayShape {
    pub heads: usize,
    pub dk: usize,
    pub dv: usize,
}

impl GatewayShape {
    /// Elements in a (H, len, Dk) query/key block.
    pub fn qk_len(&self, len: usize) -> usize {
        self.heads * len * self.dk
    }

    /// Elements in a (H, len, Dv) value block.
    pub fn v_len(&self, len: usize) -> usize {
        self.heads * len * self.dv
    }
}

/// One variable-length attention request in flight: `q`/`k` are
/// (H, len, Dk) and `v` is (H, len, Dv), flattened row-major.
pub struct GatewayRequest {
    pub id: u64,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    /// Decode-session annotation (cache handle + span start); `None`
    /// for ordinary one-shot requests.
    pub session: Option<SessionRef>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<GatewayResponse>,
}

/// Per-request result: the `(H, len - span_start, Dv)` output rows of
/// this step, flattened row-major — padding rows (and, for decode
/// steps, the already-served prefix rows) never leave the gateway.
#[derive(Debug, Clone)]
pub struct GatewayResponse {
    pub id: u64,
    pub out: Vec<f32>,
    /// Valid sequence length (full history rows for decode steps).
    pub len: usize,
    /// First row `out` covers: 0 for one-shot requests and prefills,
    /// the previously served length for decode steps.
    pub span_start: usize,
    /// Session id when this was a decode-session step.
    pub session: Option<u64>,
    /// Decode steps: whether the KV cache held the prefix (`true`) or
    /// the step fell back to a full recompute (`false`).  `None` for
    /// one-shot requests.  Either way `out` is bit-identical to the
    /// full unpadded recompute of the history.
    pub cache_hit: Option<bool>,
    /// Pad-to length of the bucket that served the request.
    pub bucket_seq_len: usize,
    /// Whether valid-length masking was applied: `true` means `out` is
    /// bit-identical to the unpadded computation of this request;
    /// `false` means static-shape semantics (padded keys participated).
    pub masked: bool,
    pub queue_time: Duration,
    pub total_time: Duration,
    pub batch_occupancy: usize,
}

#[derive(Debug, Clone)]
pub struct GatewayOptions {
    /// Deadline of each bucket's batcher (max batch comes from the
    /// bucket's own `batch_size`).
    pub max_wait: Duration,
    /// Ingress queue capacity per bucket.
    pub queue_capacity: usize,
    /// Total worker budget shared by all buckets (0 = auto: one worker
    /// per available hardware thread).
    pub workers: usize,
    /// Base seed of the per-slice PRNG streams.
    pub seed: u64,
    /// Spill fail-fast submissions into the next larger bucket when the
    /// tight bucket's queue is full.
    pub route_up: bool,
    /// Minimum output rows before an intra-slice compute-core op goes
    /// parallel (0 = `exec::DEFAULT_PAR_ROWS`).  A leased flush splits
    /// its workers between the slice axis and intra-slice tiling, so a
    /// single long-N request in a tail bucket still uses its whole
    /// lease; output bits never depend on the split.
    pub par_rows: usize,
    /// Apply valid-length masking (default).  `false` restores the
    /// static-shape semantics of the pre-masking gateway: padded K rows
    /// participate in softmax and responses depend on the bucket
    /// length.  Useful only for comparison benches.  Decode sessions
    /// require masking (the cache stores true-length histories).
    pub mask: bool,
    /// KV-cache capacity in cached sequence rows (`Σ session len`),
    /// shared by every bucket.  0 caches nothing — decode sessions
    /// still work, every step just recomputes.
    pub cache_capacity_rows: usize,
    /// Clustered-family re-cluster threshold
    /// ([`KvCacheOptions::growth`]): 1.0 (default) re-clusters every
    /// step (exact everywhere); above 1.0 reuses the frozen clustering
    /// between re-clusters.
    pub cache_growth: f64,
    /// KV-panel storage precision ([`KvCacheOptions::quant`]):
    /// [`CacheQuant::Off`] (default) keeps decode bit-identical to the
    /// full recompute; the i8 modes store ~4× more live sessions per
    /// byte of cache budget and gate hit outputs by the declared
    /// numeric tolerance instead.  With multi-host serving the same
    /// setting is declared on every dispatched shard request and
    /// applied to the degraded-mode local cache.
    pub cache_quant: CacheQuant,
    /// Evict decode sessions idle longer than this (`None` = never):
    /// the table entry and cached panels are released exactly as if
    /// the client had sent `"end"`.  Swept opportunistically on every
    /// session step and on demand via
    /// [`ServingGateway::sweep_expired`].
    pub session_ttl: Option<Duration>,
    /// Serve autoregressive (causal) attention: every flush descriptor
    /// carries the causal flag, so row `i` attends keys `j <= i` only.
    /// Requires every bucket kernel to support causal masking (the
    /// linear family) — validated at start.  Decode sessions under a
    /// causal gateway ride the O(1) recurrent-state cache path.
    pub causal: bool,
    /// `ct shard-worker` addresses.  Empty (default) = single-host
    /// serving; non-empty = every bucket fans out across these hosts
    /// through an `attention::ShardedBackend` (see module docs).
    pub shards: Vec<String>,
    /// Dispatch policy (retry/backoff/vnodes) of the sharded backends;
    /// ignored when [`GatewayOptions::shards`] is empty.
    pub shard_opts: ShardOptions,
}

impl Default for GatewayOptions {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            queue_capacity: 64,
            workers: 0, // auto
            seed: 0,
            route_up: true,
            par_rows: 0,
            mask: true,
            cache_capacity_rows: usize::MAX,
            cache_growth: 1.0,
            cache_quant: CacheQuant::Off,
            session_ttl: None,
            causal: false,
            shards: Vec::new(),
            shard_opts: ShardOptions::default(),
        }
    }
}

/// Serving metrics for one bucket.
#[derive(Default)]
pub struct BucketMetrics {
    pub completed: AtomicU64,
    /// Fail-fast submissions this bucket (and, with route-up, every
    /// larger bucket) had no queue room for.
    pub rejected: AtomicU64,
    /// Requests accepted here after overflowing a smaller bucket.
    pub routed_up: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Valid request rows (`Σ len`).
    pub valid_rows: AtomicU64,
    /// Rows in the padded batch buffers (`Σ seq_len`).
    pub padded_rows: AtomicU64,
    /// Rows the kernels actually executed (`Σ len` masked,
    /// `Σ seq_len` unmasked).
    pub computed_rows: AtomicU64,
    /// Decode steps whose cached prefix was found (only the span was
    /// solved).
    pub cache_hits: AtomicU64,
    /// Decode steps that fell back to a full recompute (prefills,
    /// evictions, stale generations).
    pub cache_misses: AtomicU64,
    /// History rows cache hits did *not* materialize
    /// (`Σ (len − executed)`, per the backend's own accounting) — the
    /// decode compute the cache actually saved this bucket; 0 for
    /// families whose exact span is a full recompute (lsh).
    pub saved_rows: AtomicU64,
    /// History rows miss fallbacks recomputed (`Σ len`).
    pub recomputed_rows: AtomicU64,
    /// Sessions this bucket accepted after outgrowing a smaller bucket
    /// (decode route-up; the cache entry migrates with them).
    pub session_route_up: AtomicU64,
    pub latency: Mutex<LatencyHistogram>,
}

impl BucketMetrics {
    /// Cache hits over decode steps, in [0, 1] (0 with no sessions).
    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits.load(Ordering::Relaxed) as f64;
        let m = self.cache_misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }

    /// Fraction of decode history rows the cache kept out of the
    /// kernels, in [0, 1]: saved / (saved + recomputed-on-miss).
    pub fn recompute_saved(&self) -> f64 {
        let saved = self.saved_rows.load(Ordering::Relaxed) as f64;
        let redone = self.recomputed_rows.load(Ordering::Relaxed) as f64;
        if saved + redone == 0.0 { 0.0 } else { saved / (saved + redone) }
    }

    /// Mean requests per executed batch.
    pub fn occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
    }

    fn waste(&self) -> PaddingWaste {
        PaddingWaste {
            valid: self.valid_rows.load(Ordering::Relaxed),
            padded: self.padded_rows.load(Ordering::Relaxed),
            computed: self.computed_rows.load(Ordering::Relaxed),
        }
    }

    /// Fraction of padded-buffer rows that were padding, in [0, 1] —
    /// the memory cost of static shapes (masking cannot reduce it).
    pub fn padding_waste(&self) -> f64 {
        self.waste().memory_ratio()
    }

    /// Fraction of *executed* rows that were padding, in [0, 1] — zero
    /// when masking is on, equal to [`BucketMetrics::padding_waste`]
    /// when it is off.
    pub fn compute_waste(&self) -> f64 {
        self.waste().compute_ratio()
    }

    /// Fraction of padded rows the kernels never executed, in [0, 1] —
    /// the compute masking saved this bucket.
    pub fn compute_saved(&self) -> f64 {
        self.waste().compute_saved()
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn percentile_us(&self, p: f64) -> f64 {
        crate::exec::lock_unpoisoned(&self.latency).percentile_us(p)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        crate::exec::lock_unpoisoned(&self.latency).mean_us()
    }
}

/// One live decode session's gateway-side state.
struct SessionState {
    generation: u64,
    /// History rows already served (the next step's span start).
    len: usize,
    /// Bucket the session is pinned to (index; `None` before prefill).
    bucket: Option<usize>,
    /// Last accepted step — the TTL sweep's idleness clock.
    last_step: Instant,
}

/// Multi-bucket native attention serving gateway (see module docs).
pub struct ServingGateway {
    shape: GatewayShape,
    router: Router,
    ingress: Vec<Channel<GatewayRequest>>, // bucket order
    metrics: Vec<Arc<BucketMetrics>>,      // bucket order
    /// Requests longer than every bucket (no candidate at all).
    overlong: AtomicU64,
    route_up: bool,
    mask: bool,
    /// Gateway-global KV cache, shared by every bucket dispatcher —
    /// route-up migrates a session without losing its panels.
    cache: Arc<KvCache>,
    sessions: Mutex<HashMap<u64, SessionState>>,
    session_ttl: Option<Duration>,
    /// Per-bucket sharded backends when multi-host serving is on
    /// (bucket order; empty for single-host).  Held here so
    /// `end_session` can release shard-side cache state too.
    sharded: Vec<Arc<ShardedBackend>>,
    next_generation: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl ServingGateway {
    /// Spawn one dispatcher per bucket.  Every bucket must be a native
    /// bucket (`Bucket::native`) whose kernel resolves in the attention
    /// registry.
    pub fn start(shape: GatewayShape, buckets: Vec<Bucket>,
                 opts: GatewayOptions) -> Result<Self> {
        if shape.heads == 0 || shape.dk == 0 || shape.dv == 0 {
            bail!("gateway shape must have heads/dk/dv >= 1, got {shape:?}");
        }
        for b in &buckets {
            if b.seq_len == 0 || b.batch_size == 0 {
                bail!("bucket needs seq_len/batch_size >= 1, got {b:?}");
            }
            if NativeBackend::by_name(&b.kernel).is_none() {
                bail!("bucket kernel {:?} not in the attention registry \
                       (native buckets only; see Bucket::native)", b.kernel);
            }
            let causal_ok = crate::attention::kernel_by_name(&b.kernel)
                .is_some_and(|k| k.supports_causal());
            if opts.causal && !causal_ok {
                bail!("bucket kernel {:?} does not support causal \
                       attention (GatewayOptions::causal needs a \
                       causal-capable family, e.g. linear)", b.kernel);
            }
        }
        let router = Router::new(buckets)?;
        let pool = Arc::new(if opts.workers == 0 {
            SharedWorkerPool::auto()
        } else {
            SharedWorkerPool::new(opts.workers)
        });
        let cache = Arc::new(KvCache::new(KvCacheOptions {
            capacity_rows: opts.cache_capacity_rows,
            growth: opts.cache_growth,
            quant: opts.cache_quant,
        }));
        // one knob governs the gateway cache and the fleet: the shard
        // backends declare the same storage policy on every request
        let shard_opts = ShardOptions {
            cache_quant: opts.cache_quant,
            ..opts.shard_opts
        };

        let mut ingress = Vec::new();
        let mut metrics = Vec::new();
        let mut workers = Vec::new();
        let mut sharded = Vec::new();
        for bucket in router.buckets() {
            let ch: Channel<GatewayRequest> =
                Channel::bounded(opts.queue_capacity.max(1));
            let m = Arc::new(BucketMetrics::default());
            ingress.push(ch.clone());
            metrics.push(m.clone());
            let backend = if opts.shards.is_empty() {
                BucketBackend::Cached(
                    CachingBackend::native(&bucket.kernel, cache.clone())
                        .ok_or_else(|| anyhow!(
                            "bucket kernel {:?} not in the attention \
                             registry", bucket.kernel))?)
            } else {
                // one fan-out backend per bucket, all over the same
                // shard list — identical rings, so a session routed up
                // between buckets still lands on its owning shard
                let sb = Arc::new(
                    ShardedBackend::over_tcp(&bucket.kernel, &opts.shards,
                                             shard_opts)
                        .ok_or_else(|| anyhow!(
                            "bucket kernel {:?} not in the attention \
                             registry", bucket.kernel))?);
                sharded.push(sb.clone());
                BucketBackend::Sharded(sb)
            };
            let worker = BucketWorker {
                backend,
                shape,
                seq_len: bucket.seq_len,
                metrics: m,
                pool: pool.clone(),
                seed: opts.seed,
                par_rows: opts.par_rows,
                mask: opts.mask,
                causal: opts.causal,
            };
            let policy = BatchPolicy {
                max_batch: bucket.batch_size,
                max_wait: opts.max_wait,
            };
            let spawned = std::thread::Builder::new()
                .name(format!("ct-gateway-{}", bucket.seq_len))
                .spawn(move || worker.dispatch(ch, policy));
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // unwind: close the queues so already-spawned
                    // dispatchers exit instead of idling forever
                    for ch in &ingress {
                        ch.close();
                    }
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Self {
            shape,
            router,
            ingress,
            metrics,
            overlong: AtomicU64::new(0),
            route_up: opts.route_up,
            mask: opts.mask,
            cache,
            sessions: Mutex::new(HashMap::new()),
            session_ttl: opts.session_ttl,
            sharded,
            next_generation: AtomicU64::new(0),
            workers,
            next_id: AtomicU64::new(0),
        })
    }

    pub fn shape(&self) -> GatewayShape {
        self.shape
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Per-bucket metrics, bucket (ascending seq_len) order.
    pub fn bucket_metrics(&self) -> &[Arc<BucketMetrics>] {
        &self.metrics
    }

    /// Requests rejected because they exceed every bucket.
    pub fn overlong_total(&self) -> u64 {
        self.overlong.load(Ordering::Relaxed)
    }

    /// Total rejections: overlong plus per-bucket backpressure.
    pub fn rejected_total(&self) -> u64 {
        self.overlong_total()
            + self
                .metrics
                .iter()
                .map(|m| m.rejected.load(Ordering::Relaxed))
                .sum::<u64>()
    }

    fn make_request(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>,
                    len: usize, session: Option<SessionRef>)
                    -> Result<(GatewayRequest,
                               mpsc::Receiver<GatewayResponse>)> {
        if len == 0 {
            return Err(anyhow!("empty request (len 0)"));
        }
        if q.len() != self.shape.qk_len(len)
            || k.len() != self.shape.qk_len(len)
            || v.len() != self.shape.v_len(len)
        {
            return Err(anyhow!(
                "gateway request shape mismatch: got q={} k={} v={}, want \
                 q=k={} v={} for len {len} with {:?}",
                q.len(), k.len(), v.len(), self.shape.qk_len(len),
                self.shape.v_len(len), self.shape));
        }
        let (tx, rx) = mpsc::channel();
        let req = GatewayRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            q,
            k,
            v,
            len,
            session,
            enqueued: Instant::now(),
            reply: tx,
        };
        Ok((req, rx))
    }

    /// Resolve one decode step: the session's cache handle and span,
    /// plus the bucket it must be offered to first.  Sessions stay
    /// pinned to their bucket until the history outgrows it, then move
    /// up to the tightest bucket that still fits (the table commits
    /// only after the step is accepted).
    fn session_step(&self, session: u64, len: usize)
                    -> Result<(SessionRef, usize)> {
        if !self.mask {
            bail!("decode sessions require valid-length masking \
                   (GatewayOptions::mask)");
        }
        // opportunistic TTL sweep: any decode traffic collects the
        // idle sessions hostile clients abandoned without an "end"
        self.sweep_expired();
        let tight = self.router.route_index(len).ok_or_else(|| {
            self.overlong.fetch_add(1, Ordering::Relaxed);
            anyhow!("session {session} history of {len} rows exceeds \
                     every bucket (max {})", self.router.max_len())
        })?;
        // read-only: the table entry is created only when the step is
        // accepted (commit_session), so a rejected or malformed first
        // request leaks no session state
        let (generation, span, pinned) = {
            let table = crate::exec::lock_unpoisoned(&self.sessions);
            match table.get(&session) {
                Some(st) => {
                    if len <= st.len {
                        bail!("session {session} step of len {len} does \
                               not extend the {} rows already served",
                              st.len);
                    }
                    (st.generation, st.len, st.bucket)
                }
                None => (self
                             .next_generation
                             .fetch_add(1, Ordering::Relaxed),
                         0, None),
            }
        };
        // pinned bucket, routed up when the history outgrew it
        let target = pinned.map_or(tight, |b| b.max(tight));
        Ok((SessionRef {
            cache: CacheRef { session, generation },
            span_start: span,
        }, target))
    }

    /// Record a successfully enqueued step: create/advance the
    /// session's table entry and (re-)pin the bucket, counting decode
    /// route-ups.
    fn commit_session(&self, session: u64, generation: u64, len: usize,
                      bucket: usize) {
        let mut table = crate::exec::lock_unpoisoned(&self.sessions);
        let st = table.entry(session).or_insert(SessionState {
            generation,
            len: 0,
            bucket: None,
            last_step: Instant::now(),
        });
        if let Some(prev) = st.bucket {
            if bucket > prev {
                self.metrics[bucket]
                    .session_route_up
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
        st.len = len;
        st.bucket = Some(bucket);
        st.last_step = Instant::now();
    }

    /// Fail-fast decode-session submit: the full history so far plus
    /// the session id.  The reply carries only this step's new rows
    /// (`span_start..len`), bit-identical to recomputing the history
    /// unpadded.  See the module docs for pinning and route-up.
    pub fn submit_session(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>,
                          len: usize, session: u64)
                          -> Result<mpsc::Receiver<GatewayResponse>> {
        let (sref, target) = self.session_step(session, len)?;
        let (req, rx) = self.make_request(q, k, v, len, Some(sref))?;
        let rest = (target + 1)..self.ingress.len();
        match offer(&self.ingress, target, rest, self.route_up, req) {
            Ok(idx) => {
                if idx != target {
                    self.metrics[idx]
                        .routed_up
                        .fetch_add(1, Ordering::Relaxed);
                }
                self.commit_session(session, sref.cache.generation,
                                    len, idx);
                Ok(rx)
            }
            Err(_) => {
                self.metrics[target]
                    .rejected
                    .fetch_add(1, Ordering::Relaxed);
                Err(anyhow!(
                    "bucket N={} queue full (backpressure{})",
                    self.router.buckets()[target].seq_len,
                    if self.route_up { ", route-up exhausted" }
                    else { "" }))
            }
        }
    }

    /// Blocking decode-session submit: waits out backpressure on the
    /// session's (possibly grown) pinned bucket.
    pub fn submit_session_blocking(&self, q: Vec<f32>, k: Vec<f32>,
                                   v: Vec<f32>, len: usize, session: u64)
                                   -> Result<mpsc::Receiver<GatewayResponse>>
    {
        let (sref, target) = self.session_step(session, len)?;
        let (req, rx) = self.make_request(q, k, v, len, Some(sref))?;
        self.ingress[target]
            .send(req)
            .map_err(|_| anyhow!("gateway shut down"))?;
        self.commit_session(session, sref.cache.generation, len, target);
        Ok(rx)
    }

    /// Forget a session: its gateway state and cached panels are
    /// dropped, and the generation counter guarantees a later session
    /// under the same id can never alias the old cache entry.  With
    /// multi-host serving, the release also reaches the session's
    /// owning shard (every bucket's backend, since a routed-up session
    /// may have fallen back to any of their local caches).
    ///
    /// Idempotent: ending an unknown (or already-ended) session is a
    /// harmless no-op that creates no state.  Returns whether the
    /// session was live — the wire protocol reports it as
    /// `"was_live"` so clients can distinguish a real teardown from a
    /// duplicate or misaddressed `end`.
    pub fn end_session(&self, session: u64) -> bool {
        let was_live =
            crate::exec::lock_unpoisoned(&self.sessions).remove(&session).is_some();
        self.cache.invalidate(session);
        for sb in &self.sharded {
            sb.end_session(session);
        }
        was_live
    }

    /// Evict every session idle past [`GatewayOptions::session_ttl`]
    /// (no-op without a TTL); returns how many were released.  Called
    /// opportunistically on each decode step; long-running servers with
    /// bursty session traffic should also call it periodically (the
    /// `ct gateway` command runs a sweeper thread).
    pub fn sweep_expired(&self) -> usize {
        let Some(ttl) = self.session_ttl else { return 0 };
        let now = Instant::now();
        // collect under the lock, release outside it: end_session
        // re-locks the table and talks to shards
        let expired: Vec<u64> = {
            let table = crate::exec::lock_unpoisoned(&self.sessions);
            table.iter()
                .filter(|(_, st)| now.duration_since(st.last_step) >= ttl)
                .map(|(&sid, _)| sid)
                .collect()
        };
        for &sid in &expired {
            log::debug!("session {sid} idle past {ttl:?} — evicting");
            self.end_session(sid);
        }
        expired.len()
    }

    /// Live decode sessions in the table.
    pub fn live_sessions(&self) -> usize {
        crate::exec::lock_unpoisoned(&self.sessions).len()
    }

    /// The gateway-global KV cache (counters, capacity introspection).
    pub fn cache(&self) -> &Arc<KvCache> {
        &self.cache
    }

    /// Per-bucket shard-side cache counters, bucket order — aggregated
    /// from the snapshots workers return on session replies (satellite
    /// telemetry; see [`ShardedBackend::cache_stats`]).  Empty for
    /// single-host gateways.
    pub fn shard_cache_stats(&self) -> Vec<ShardCacheStats> {
        self.sharded.iter().map(|sb| sb.cache_stats()).collect()
    }

    /// Fail-fast submit with route-up admission control: try the
    /// tightest bucket, spill upward on a full queue, reject with a
    /// backpressure error when every candidate is full.
    pub fn submit(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>, len: usize)
                  -> Result<mpsc::Receiver<GatewayResponse>> {
        let (req, rx) = self.make_request(q, k, v, len, None)?;
        let mut candidates = self.router.route_candidates(len);
        let Some(tight) = candidates.next() else {
            self.overlong.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(
                "request of length {len} exceeds every bucket (max {})",
                self.router.max_len()));
        };
        match offer(&self.ingress, tight, candidates, self.route_up, req) {
            Ok(idx) => {
                if idx != tight {
                    self.metrics[idx]
                        .routed_up
                        .fetch_add(1, Ordering::Relaxed);
                }
                Ok(rx)
            }
            Err(_) => {
                self.metrics[tight].rejected.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!(
                    "bucket N={} queue full (backpressure{})",
                    self.router.buckets()[tight].seq_len,
                    if self.route_up { ", route-up exhausted" } else { "" }))
            }
        }
    }

    /// Blocking submit: waits out backpressure on the tightest bucket
    /// instead of failing or routing up.
    pub fn submit_blocking(&self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>,
                           len: usize)
                           -> Result<mpsc::Receiver<GatewayResponse>> {
        let (req, rx) = self.make_request(q, k, v, len, None)?;
        let idx = self.router.route_index(len).ok_or_else(|| {
            self.overlong.fetch_add(1, Ordering::Relaxed);
            anyhow!("request of length {len} exceeds every bucket (max {})",
                    self.router.max_len())
        })?;
        self.ingress[idx]
            .send(req)
            .map_err(|_| anyhow!("gateway shut down"))?;
        Ok(rx)
    }

    pub fn shutdown(self) {
        for ch in &self.ingress {
            ch.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Offer `req` to the tight bucket, then (with route-up) each larger
/// candidate in order.  Ok(accepting index) or Err(req) when every
/// tried queue was full.
fn offer<T>(channels: &[Channel<T>], tight: usize,
            rest: impl Iterator<Item = usize>, route_up: bool, req: T)
            -> Result<usize, T> {
    let mut req = match channels[tight].try_send(req) {
        Ok(()) => return Ok(tight),
        Err(back) => back,
    };
    if route_up {
        for idx in rest {
            match channels[idx].try_send(req) {
                Ok(()) => return Ok(idx),
                Err(back) => req = back,
            }
        }
    }
    Err(req)
}

/// Pad variable-length `(data, len)` blocks — each `(H, len, D)`
/// row-major — into one static (B, H, seq_len, D) batch, zero-filling
/// rows `len..seq_len` of every head.
///
/// Slot order is block order, so this is exactly the batch a gateway
/// dispatcher assembles from a flush — the reference the gateway
/// determinism property test replays through
/// `attention::solve_batch_seq`.
pub fn pad_batch(blocks: &[(&[f32], usize)], heads: usize, seq_len: usize,
                 d: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(blocks.len(), heads, seq_len, d);
    for (slot, (data, len)) in blocks.iter().enumerate() {
        assert!(*len <= seq_len,
                "block of len {len} exceeds bucket seq_len {seq_len}");
        assert_eq!(data.len(), heads * len * d,
                   "block data is not (H, len, D)");
        for h in 0..heads {
            let dst = out.slice_mut(slot * heads + h);
            dst[..len * d]
                .copy_from_slice(&data[h * len * d..(h + 1) * len * d]);
        }
    }
    out
}

/// The (H, len, Dv) valid rows of batch slot `slot` in a padded
/// (B, H, seq_len, Dv) kernel output — the inverse of [`pad_batch`] on
/// the output side.  This is the extraction the gateway applies before
/// replying; the determinism property test and the `gateway` bench use
/// it to slice the sequential reference run identically.
pub fn valid_rows(out: &BatchMatrix, slot: usize, len: usize) -> Vec<f32> {
    span_rows(out, slot, 0, len)
}

/// The `(H, len - span_start, Dv)` span rows of batch slot `slot` — the
/// decode-step sibling of [`valid_rows`]: a session reply carries only
/// the rows this step computed.
pub fn span_rows(out: &BatchMatrix, slot: usize, span_start: usize,
                 len: usize) -> Vec<f32> {
    debug_assert!(span_start <= len && len <= out.rows);
    let (n, dv, heads) = (out.rows, out.cols, out.heads);
    let mut rows = Vec::with_capacity(heads * (len - span_start) * dv);
    for h in 0..heads {
        let base = (slot * heads + h) * n * dv;
        rows.extend_from_slice(
            &out.data[base + span_start * dv..base + len * dv]);
    }
    rows
}

/// The unpadded reference for one co-batched request: solve slot
/// `slot`'s (H, len, D) blocks head by head against the gateway's
/// per-slice seed schedule (`slice_stream(seed, slot·H + h)`), with no
/// padding anywhere.  A masked gateway response must equal this
/// bit-for-bit — the end-to-end statement of the masking contract,
/// asserted by the `gateway` bench, the ragged proptest and the
/// integration tests.
#[allow(clippy::too_many_arguments)]
pub fn unpadded_reference(kernel: &dyn AttentionKernel, shape: GatewayShape,
                          seed: u64, slot: usize, q: &[f32], k: &[f32],
                          v: &[f32], len: usize) -> Vec<f32> {
    unpadded_reference_impl(kernel, shape, seed, slot, q, k, v, len, false)
}

/// [`unpadded_reference`] for a causal gateway: the per-head problems
/// carry the causal flag, so the reference is the autoregressive
/// computation a `GatewayOptions { causal: true, … }` response must
/// match bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn unpadded_reference_causal(kernel: &dyn AttentionKernel,
                                 shape: GatewayShape, seed: u64,
                                 slot: usize, q: &[f32], k: &[f32],
                                 v: &[f32], len: usize) -> Vec<f32> {
    unpadded_reference_impl(kernel, shape, seed, slot, q, k, v, len, true)
}

#[allow(clippy::too_many_arguments)]
fn unpadded_reference_impl(kernel: &dyn AttentionKernel,
                           shape: GatewayShape, seed: u64, slot: usize,
                           q: &[f32], k: &[f32], v: &[f32], len: usize,
                           causal: bool) -> Vec<f32> {
    assert_eq!(q.len(), shape.qk_len(len), "q block is not (H, len, Dk)");
    assert_eq!(k.len(), shape.qk_len(len), "k block is not (H, len, Dk)");
    assert_eq!(v.len(), shape.v_len(len), "v block is not (H, len, Dv)");
    let (dk, dv) = (shape.dk, shape.dv);
    let mut out = Vec::with_capacity(shape.v_len(len));
    for h in 0..shape.heads {
        let s = (slot * shape.heads + h) as u64;
        let mut rng = crate::prng::slice_stream(seed, s);
        let qm = Matrix::from_vec(len, dk,
                                  q[h * len * dk..(h + 1) * len * dk]
                                      .to_vec());
        let km = Matrix::from_vec(len, dk,
                                  k[h * len * dk..(h + 1) * len * dk]
                                      .to_vec());
        let vm = Matrix::from_vec(len, dv,
                                  v[h * len * dv..(h + 1) * len * dv]
                                      .to_vec());
        let o = kernel.solve(&AttnProblem::new(&qm, &km, &vm)
                                 .with_causal(causal),
                             &mut rng, &ExecCtx::sequential());
        out.extend_from_slice(&o.data);
    }
    out
}

/// The unpadded full-history recompute of one decode-session step: the
/// oracle a session reply must match bit-for-bit, hit or miss.
///
/// `q`/`k`/`v` are the step's full (H, len, D) history blocks; the
/// per-head streams come from the *session* (`prng::session_seed`), not
/// a batch slot, which is what makes the reply invariant to co-batched
/// traffic.  Returns the `(H, len - span_start, Dv)` span rows, exactly
/// like the reply's `out`.
#[allow(clippy::too_many_arguments)]
pub fn session_reference(kernel: &dyn AttentionKernel, shape: GatewayShape,
                         seed: u64, session: u64, q: &[f32], k: &[f32],
                         v: &[f32], len: usize, span_start: usize)
                         -> Vec<f32> {
    session_reference_impl(kernel, shape, seed, session, q, k, v, len,
                           span_start, false)
}

/// [`session_reference`] for a causal gateway: the full-history
/// recompute is autoregressive, so this is the oracle a causal decode
/// step — recurrent-state hit or full-recompute miss — must match
/// bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn session_reference_causal(kernel: &dyn AttentionKernel,
                                shape: GatewayShape, seed: u64,
                                session: u64, q: &[f32], k: &[f32],
                                v: &[f32], len: usize, span_start: usize)
                                -> Vec<f32> {
    session_reference_impl(kernel, shape, seed, session, q, k, v, len,
                           span_start, true)
}

#[allow(clippy::too_many_arguments)]
fn session_reference_impl(kernel: &dyn AttentionKernel,
                          shape: GatewayShape, seed: u64, session: u64,
                          q: &[f32], k: &[f32], v: &[f32], len: usize,
                          span_start: usize, causal: bool) -> Vec<f32> {
    assert_eq!(q.len(), shape.qk_len(len), "q block is not (H, len, Dk)");
    assert_eq!(k.len(), shape.qk_len(len), "k block is not (H, len, Dk)");
    assert_eq!(v.len(), shape.v_len(len), "v block is not (H, len, Dv)");
    assert!(span_start < len, "span must leave a row");
    let (dk, dv) = (shape.dk, shape.dv);
    let seed2 = crate::prng::session_seed(seed, session);
    let mut out =
        Vec::with_capacity(shape.heads * (len - span_start) * dv);
    for h in 0..shape.heads {
        let mut rng = crate::prng::slice_stream(seed2, h as u64);
        let qm = Matrix::from_vec(len, dk,
                                  q[h * len * dk..(h + 1) * len * dk]
                                      .to_vec());
        let km = Matrix::from_vec(len, dk,
                                  k[h * len * dk..(h + 1) * len * dk]
                                      .to_vec());
        let vm = Matrix::from_vec(len, dv,
                                  v[h * len * dv..(h + 1) * len * dv]
                                      .to_vec());
        let o = kernel.solve(&AttnProblem::new(&qm, &km, &vm)
                                 .with_causal(causal),
                             &mut rng, &ExecCtx::sequential());
        out.extend_from_slice(&o.data[span_start * dv..]);
    }
    out
}

/// What a bucket dispatcher executes flushes through — the two
/// concrete ends of the [`AttentionBackend`] seam the gateway serves
/// from.
enum BucketBackend {
    /// Single-host: native kernel behind the gateway-global KV cache.
    Cached(CachingBackend),
    /// Multi-host: fan-out across shard workers; sessions are cached
    /// on their owning shard, not in the gateway-global cache.
    Sharded(Arc<ShardedBackend>),
}

impl BucketBackend {
    fn execute(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx)
               -> BatchMatrix {
        match self {
            Self::Cached(b) => b.execute(batch, ctx),
            Self::Sharded(b) => b.execute(batch, ctx),
        }
    }

    fn execute_with_report(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx)
                           -> (BatchMatrix, Vec<SeqOutcome>) {
        match self {
            Self::Cached(b) => b.execute_with_report(batch, ctx),
            Self::Sharded(b) => b.execute_with_report(batch, ctx),
        }
    }
}

/// One bucket's dispatcher state: the backend it drives plus everything
/// a flush needs.  Keeping it a struct (instead of a nine-argument
/// function) is what lets the backend seam swap implementations without
/// touching the dispatch loop.
struct BucketWorker {
    backend: BucketBackend,
    shape: GatewayShape,
    seq_len: usize,
    metrics: Arc<BucketMetrics>,
    pool: Arc<SharedWorkerPool>,
    seed: u64,
    par_rows: usize,
    mask: bool,
    causal: bool,
}

impl BucketWorker {
    /// The dispatcher loop: drain → batch → execute → reply.
    fn dispatch(self, ch: Channel<GatewayRequest>, policy: BatchPolicy) {
        let mut batcher: Batcher<GatewayRequest> = Batcher::new(policy);
        loop {
            let wait = batcher.next_wait(Instant::now());
            let item = ch.recv_timeout(wait);
            let mut ready: Option<Vec<GatewayRequest>> = None;
            match item {
                Ok(Some(req)) => {
                    ready = batcher.push(req, Instant::now());
                }
                Ok(None) => {
                    if let Some(batch) = batcher.take() {
                        self.run_flush(batch);
                    }
                    return;
                }
                Err(()) => {}
            }
            if ready.is_none() {
                ready = batcher.poll_deadline(Instant::now());
            }
            if let Some(batch) = ready {
                self.run_flush(batch);
            }
        }
    }

    /// Execute one flushed co-batch through the backend and reply.
    fn run_flush(&self, batch: Vec<GatewayRequest>) {
        let (shape, seq_len) = (self.shape, self.seq_len);
        let occupancy = batch.len();
        let qb: Vec<(&[f32], usize)> =
            batch.iter().map(|r| (&r.q[..], r.len)).collect();
        let kb: Vec<(&[f32], usize)> =
            batch.iter().map(|r| (&r.k[..], r.len)).collect();
        let vb: Vec<(&[f32], usize)> =
            batch.iter().map(|r| (&r.v[..], r.len)).collect();
        let q = pad_batch(&qb, shape.heads, seq_len, shape.dk);
        let k = pad_batch(&kb, shape.heads, seq_len, shape.dk);
        let v = pad_batch(&vb, shape.heads, seq_len, shape.dv);
        let lens: Vec<usize> = batch.iter().map(|r| r.len).collect();
        let queue_times: Vec<Duration> =
            batch.iter().map(|r| r.enqueued.elapsed()).collect();

        // the request descriptor: the true lengths ride along, so the
        // backend masks padded rows out of the compute entirely, and
        // decode steps carry their cache handle + span
        let sessions: Vec<Option<SessionRef>> =
            batch.iter().map(|r| r.session).collect();
        let any_session = sessions.iter().any(|s| s.is_some());
        let mut descriptor = AttnBatch::new(&q, &k, &v, self.seed)
            .with_causal(self.causal);
        if self.mask {
            descriptor = descriptor.with_lens(&lens);
        }
        if any_session {
            descriptor = descriptor.with_sessions(&sessions);
        }

        // one lease per flush: live leases never sum above the shared
        // budget (a flush queues here when it is spent).  The leased
        // workers split between the slice axis and intra-slice tiled
        // compute (solve_batch), so a lone long-N request still uses
        // them all — without changing a single output bit.
        let lease = self.pool.lease();
        let ctx = ExecCtx::with_par_rows(*lease, self.par_rows);
        let (out, outcomes) = if any_session {
            self.backend.execute_with_report(&descriptor, &ctx)
        } else {
            (self.backend.execute(&descriptor, &ctx),
             vec![SeqOutcome::Bypass; occupancy])
        };
        drop(lease);

        let metrics = &self.metrics;
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_items
            .fetch_add(occupancy as u64, Ordering::Relaxed);

        for (slot, req) in batch.into_iter().enumerate() {
            let span = req.session.map_or(0, |s| s.span_start);
            let rows = span_rows(&out, slot, span, req.len);
            let total = req.enqueued.elapsed();
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            // the masked/unmasked executed-rows rule lives in
            // PaddingWaste, not here — accumulate a per-request delta
            // through it and publish the counters it produced
            let mut delta = PaddingWaste::default();
            if self.mask {
                delta.add_masked(req.len, seq_len);
            } else {
                delta.add(req.len, seq_len);
            }
            let cache_hit = match outcomes[slot] {
                SeqOutcome::Hit { computed_rows, .. } => {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    // honest accounting: the backend reports what it
                    // actually materialized (the span for incremental
                    // families, the full history for the
                    // recompute-with-extraction ones), so `saved` is
                    // real work avoided, never phantom savings
                    let spared = req.len.saturating_sub(computed_rows);
                    metrics
                        .saved_rows
                        .fetch_add(spared as u64, Ordering::Relaxed);
                    delta.computed = computed_rows as u64;
                    Some(true)
                }
                SeqOutcome::Miss { recomputed_rows } => {
                    metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                    metrics.recomputed_rows.fetch_add(
                        recomputed_rows as u64, Ordering::Relaxed);
                    Some(false)
                }
                SeqOutcome::Bypass => None,
            };
            metrics.valid_rows.fetch_add(delta.valid, Ordering::Relaxed);
            metrics.padded_rows.fetch_add(delta.padded, Ordering::Relaxed);
            metrics
                .computed_rows
                .fetch_add(delta.computed, Ordering::Relaxed);
            crate::exec::lock_unpoisoned(&metrics.latency).record(total);
            let _ = req.reply.send(GatewayResponse {
                id: req.id,
                out: rows,
                len: req.len,
                span_start: span,
                session: req.session.map(|s| s.cache.session),
                cache_hit,
                bucket_seq_len: seq_len,
                masked: self.mask,
                queue_time: queue_times[slot],
                total_time: total,
                batch_occupancy: occupancy,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// synthetic traffic (shared by the gateway bench, the CLI and tests)
// ---------------------------------------------------------------------------

/// One request of a synthetic trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    /// Decode-session id: the item is one step of a growing history
    /// (replayed in order through `submit_session_blocking`).  `None`
    /// = ordinary one-shot request.
    pub session: Option<u64>,
}

/// Mixed-length synthetic trace: lengths are log₂-uniform in
/// `[min_len, max_len]` (short requests as common as long ones — the
/// utterance-length mix the ASR workload serves), tensors standard
/// normal from `seed`.
pub fn synthetic_trace(shape: GatewayShape, min_len: usize, max_len: usize,
                       count: usize, seed: u64) -> Vec<TraceItem> {
    assert!(min_len >= 1 && min_len <= max_len, "bad trace length range");
    let mut rng = Xoshiro256::new(seed);
    let (lo, hi) = ((min_len as f64).log2(), (max_len as f64).log2());
    (0..count)
        .map(|_| {
            let len = 2f64
                .powf(lo + rng.next_f64() * (hi - lo))
                .round() as usize;
            let len = len.clamp(min_len, max_len);
            TraceItem {
                q: rng.normal_vec(shape.qk_len(len)),
                k: rng.normal_vec(shape.qk_len(len)),
                v: rng.normal_vec(shape.v_len(len)),
                len,
                session: None,
            }
        })
        .collect()
}

/// Multi-step decode-session trace: `sessions` concurrent sessions,
/// each a prefill of `prefill` rows followed by `steps` decode steps of
/// `step_len` new rows.  Every item carries the session's *full
/// history so far* (the submit-session protocol), and the prefixes are
/// bit-identical across steps — each session's history is generated
/// once and sliced — so the cache-hit path sees exactly the bytes it
/// cached.  Items are emitted step-round-robin across sessions;
/// [`replay_blocking`] keeps each session's steps in order.
pub fn synthetic_decode_trace(shape: GatewayShape, prefill: usize,
                              steps: usize, step_len: usize,
                              sessions: usize, seed: u64)
                              -> Vec<TraceItem> {
    assert!(prefill >= 1 && step_len >= 1 && sessions >= 1,
            "bad decode trace parameters");
    let total = prefill + steps * step_len;
    let mut rng = Xoshiro256::new(seed);
    let histories: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..sessions)
        .map(|_| (rng.normal_vec(shape.qk_len(total)),
                  rng.normal_vec(shape.qk_len(total)),
                  rng.normal_vec(shape.v_len(total))))
        .collect();
    // (H, total, D) row-major → the (H, len, D) prefix is per-head
    let prefix = |data: &[f32], d: usize, len: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(shape.heads * len * d);
        for h in 0..shape.heads {
            let base = h * total * d;
            out.extend_from_slice(&data[base..base + len * d]);
        }
        out
    };
    let mut items = Vec::new();
    for step in 0..=steps {
        let len = prefill + step * step_len;
        for (sid, (q, k, v)) in histories.iter().enumerate() {
            items.push(TraceItem {
                q: prefix(q, shape.dk, len),
                k: prefix(k, shape.dk, len),
                v: prefix(v, shape.dv, len),
                len,
                session: Some(sid as u64),
            });
        }
    }
    items
}

/// Replay a trace through the gateway from `clients` concurrent blocking
/// submitters; responses come back in trace order.  One-shot items
/// round-robin across clients; session items pin to the lane
/// `session % clients`, so a session's steps replay strictly in trace
/// order (each step waits for the previous reply — the span
/// bookkeeping decode requires).  Every trace length must fit some
/// bucket.
#[allow(clippy::expect_used)] // bench/oracle trace driver, not the serving path
pub fn replay_blocking(gw: &ServingGateway, trace: Vec<TraceItem>,
                       clients: usize) -> Vec<GatewayResponse> {
    let n = trace.len();
    let clients = clients.clamp(1, n.max(1));
    let mut lanes: Vec<Vec<(usize, TraceItem)>> =
        (0..clients).map(|_| Vec::new()).collect();
    for (i, item) in trace.into_iter().enumerate() {
        let lane = match item.session {
            Some(sid) => sid as usize % clients,
            None => i % clients,
        };
        lanes[lane].push((i, item));
    }
    let mut out: Vec<Option<GatewayResponse>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = lanes
            .into_iter()
            .map(|lane| {
                scope.spawn(move || {
                    let mut got = Vec::with_capacity(lane.len());
                    for (i, item) in lane {
                        let rx = match item.session {
                            Some(sid) => gw.submit_session_blocking(
                                item.q, item.k, item.v, item.len, sid),
                            None => gw.submit_blocking(item.q, item.k,
                                                       item.v, item.len),
                        }
                        // ct-lint: allow(panic-expect, reason = "replay_blocking is the bench/oracle trace driver, not the serving path; a rejected trace item is a harness bug")
                        .expect("trace item rejected");
                        // ct-lint: allow(panic-expect, reason = "bench/oracle trace driver: a dropped reply means the gateway under test died")
                        got.push((i, rx.recv().expect("gateway dropped \
                                                       a trace request")));
                    }
                    got
                })
            })
            .collect();
        for h in handles {
            // ct-lint: allow(panic-expect, reason = "bench/oracle trace driver: propagate a client thread's panic to the harness")
            for (i, resp) in h.join().expect("replay client panicked") {
                out[i] = Some(resp);
            }
        }
    });
    out.into_iter()
        // ct-lint: allow(panic-expect, reason = "bench/oracle trace driver: every index was populated by construction")
        .map(|r| r.expect("trace response missing"))
        .collect()
}

/// Column headers matching [`bucket_report`] rows.  `mem waste %` is
/// the padded-buffer fraction that was padding (static shapes always
/// pay it); `cmp waste %` is the *executed*-row fraction that was
/// padding — 0.0 when masking is on, equal to `mem waste %` when off.
/// `hit %` is the KV-cache hit rate over decode steps and
/// `saved %` the fraction of decode history rows the cache kept out of
/// the kernels ([`BucketMetrics::recompute_saved`]) — both 0.0 for
/// buckets that served no sessions.  `shard hit %` is the same hit
/// rate measured *worker-side* from the counter snapshots shard
/// replies carry ([`ServingGateway::shard_cache_stats`]); `-` for
/// single-host gateways.
pub const BUCKET_REPORT_HEADERS: [&str; 14] =
    ["N", "kernel", "done", "routed-up", "rejected", "occupancy",
     "p50 ms", "p99 ms", "rows/s", "mem waste %", "cmp waste %",
     "hit %", "saved %", "shard hit %"];

/// Per-bucket serving report, one row of strings per bucket (ascending
/// seq_len), ready for a `benchlib::Table` with
/// [`BUCKET_REPORT_HEADERS`].  `wall_s` is the measurement window used
/// for rows/sec (valid rows only — padding rows are reported as waste,
/// not throughput).
pub fn bucket_report(gw: &ServingGateway, wall_s: f64) -> Vec<Vec<String>> {
    let shard_stats = gw.shard_cache_stats(); // empty for single-host
    gw.router()
        .buckets()
        .iter()
        .zip(gw.bucket_metrics())
        .enumerate()
        .map(|(i, (b, m))| {
            let rows = m.valid_rows.load(Ordering::Relaxed);
            let shard_hit = match shard_stats.get(i) {
                None => "-".to_string(),
                Some(s) => {
                    let lookups = (s.hits + s.misses) as f64;
                    format!("{:.1}", if lookups == 0.0 { 0.0 } else {
                        100.0 * s.hits as f64 / lookups
                    })
                }
            };
            vec![
                b.seq_len.to_string(),
                b.kernel.clone(),
                m.completed.load(Ordering::Relaxed).to_string(),
                m.routed_up.load(Ordering::Relaxed).to_string(),
                m.rejected.load(Ordering::Relaxed).to_string(),
                format!("{:.2}", m.occupancy()),
                format!("{:.2}", m.percentile_us(50.0) / 1e3),
                format!("{:.2}", m.percentile_us(99.0) / 1e3),
                format!("{:.0}",
                        if wall_s > 0.0 { rows as f64 / wall_s }
                        else { 0.0 }),
                format!("{:.1}", 100.0 * m.padding_waste()),
                format!("{:.1}", 100.0 * m.compute_waste()),
                format!("{:.1}", 100.0 * m.cache_hit_rate()),
                format!("{:.1}", 100.0 * m.recompute_saved()),
                shard_hit,
            ]
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::attention::{kernel_by_name, solve_batch_seq};

    const SHAPE: GatewayShape = GatewayShape { heads: 2, dk: 8, dv: 8 };

    fn block(len: usize, d: usize, seed: u64) -> Vec<f32> {
        Xoshiro256::new(seed).normal_vec(SHAPE.heads * len * d)
    }

    #[test]
    fn pad_batch_places_heads_and_zero_fills() {
        // one block, 2 heads, len 2 -> padded to 3 rows, d=2
        let data: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        let out = pad_batch(&[(&data, 2)], 2, 3, 2);
        assert_eq!((out.batch, out.heads, out.rows, out.cols), (1, 2, 3, 2));
        // head 0: rows 1,2 then zeros
        assert_eq!(out.slice_matrix(0).data,
                   vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0]);
        // head 1: rows 3,4 then zeros
        assert_eq!(out.slice_matrix(1).data,
                   vec![5.0, 6.0, 7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn offer_routes_up_on_overflow() {
        let chans: Vec<Channel<u32>> =
            (0..3).map(|_| Channel::bounded(1)).collect();
        chans[0].try_send(9).unwrap(); // tight bucket full
        // route-up spills to the next candidate
        assert_eq!(offer(&chans, 0, 1..3, true, 1), Ok(1));
        // with route-up disabled the same state rejects
        assert_eq!(offer(&chans, 0, 1..3, false, 2), Err(2));
        // every queue full -> rejected with the request handed back
        chans[1].try_send(9).unwrap_err(); // already holds the spilled 1
        chans[2].try_send(9).unwrap();
        assert_eq!(offer(&chans, 0, 1..3, true, 3), Err(3));
    }

    fn same_bits(got: &[f32], want: &[f32]) -> bool {
        got.len() == want.len()
            && got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits())
    }

    #[test]
    fn masked_cobatch_matches_the_unpadded_reference_bit_for_bit() {
        let (l0, l1) = (20, 32);
        let (q0, k0, v0) =
            (block(l0, 8, 1), block(l0, 8, 2), block(l0, 8, 3));
        let (q1, k1, v1) =
            (block(l1, 8, 4), block(l1, 8, 5), block(l1, 8, 6));
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("clustered-4", 32, 2)],
            GatewayOptions {
                // generous deadline: the batch must form on the size
                // trigger even if CI stalls between the two submits
                max_wait: Duration::from_secs(10),
                queue_capacity: 8,
                workers: 4,
                seed: 17,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let rx0 = gw
            .submit_blocking(q0.clone(), k0.clone(), v0.clone(), l0)
            .unwrap();
        let rx1 = gw
            .submit_blocking(q1.clone(), k1.clone(), v1.clone(), l1)
            .unwrap();
        let r0 = rx0.recv_timeout(Duration::from_secs(30)).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r0.batch_occupancy, 2, "requests were not co-batched");
        assert!(r0.masked && r1.masked);

        // reference 1: the sequential loop over the same ragged
        // descriptor (lens attached) — the determinism contract
        let q = pad_batch(&[(&q0, l0), (&q1, l1)], SHAPE.heads, 32,
                          SHAPE.dk);
        let k = pad_batch(&[(&k0, l0), (&k1, l1)], SHAPE.heads, 32,
                          SHAPE.dk);
        let v = pad_batch(&[(&v0, l0), (&v1, l1)], SHAPE.heads, 32,
                          SHAPE.dv);
        let lens = [l0, l1];
        let kernel = kernel_by_name("clustered-4").unwrap();
        let want = solve_batch_seq(
            kernel.as_ref(),
            &AttnBatch::new(&q, &k, &v, 17).with_lens(&lens));
        assert!(same_bits(&r0.out, &valid_rows(&want, 0, l0)));
        assert!(same_bits(&r1.out, &valid_rows(&want, 1, l1)));

        // reference 2: the fully-unpadded per-request computation — the
        // masking contract end-to-end (no padded tensor anywhere)
        let u0 = unpadded_reference(kernel.as_ref(), SHAPE, 17, 0, &q0,
                                    &k0, &v0, l0);
        let u1 = unpadded_reference(kernel.as_ref(), SHAPE, 17, 1, &q1,
                                    &k1, &v1, l1);
        assert!(same_bits(&r0.out, &u0),
                "masked response != unpadded computation (slot 0)");
        assert!(same_bits(&r1.out, &u1),
                "masked response != unpadded computation (slot 1)");
        gw.shutdown();
    }

    #[test]
    fn unmasked_gateway_keeps_static_shape_semantics() {
        let (l0, l1) = (20, 32);
        let (q0, k0, v0) =
            (block(l0, 8, 7), block(l0, 8, 8), block(l0, 8, 9));
        let (q1, k1, v1) =
            (block(l1, 8, 10), block(l1, 8, 11), block(l1, 8, 12));
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("clustered-4", 32, 2)],
            GatewayOptions {
                max_wait: Duration::from_secs(10),
                mask: false, // historical static-shape semantics
                workers: 4,
                seed: 17,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let rx0 = gw
            .submit_blocking(q0.clone(), k0.clone(), v0.clone(), l0)
            .unwrap();
        let rx1 = gw
            .submit_blocking(q1.clone(), k1.clone(), v1.clone(), l1)
            .unwrap();
        let r0 = rx0.recv_timeout(Duration::from_secs(30)).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r0.batch_occupancy, 2);
        assert!(!r0.masked && !r1.masked);

        // reference: the dense (no-lens) sequential loop over the same
        // padded batch — exactly the pre-masking gateway contract
        let q = pad_batch(&[(&q0, l0), (&q1, l1)], SHAPE.heads, 32,
                          SHAPE.dk);
        let k = pad_batch(&[(&k0, l0), (&k1, l1)], SHAPE.heads, 32,
                          SHAPE.dk);
        let v = pad_batch(&[(&v0, l0), (&v1, l1)], SHAPE.heads, 32,
                          SHAPE.dv);
        let kernel = kernel_by_name("clustered-4").unwrap();
        let want =
            solve_batch_seq(kernel.as_ref(), &AttnBatch::new(&q, &k, &v,
                                                             17));
        assert!(same_bits(&r0.out, &valid_rows(&want, 0, l0)));
        assert!(same_bits(&r1.out, &valid_rows(&want, 1, l1)));
        // unmasked metrics: compute waste equals memory waste
        let m = &gw.bucket_metrics()[0];
        assert!((m.compute_waste() - m.padding_waste()).abs() < 1e-12);
        assert_eq!(m.compute_saved(), 0.0);
        gw.shutdown();
    }

    #[test]
    fn gateway_serves_mixed_lengths_and_accumulates_bucket_metrics() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 4),
                 Bucket::native("full", 32, 4)],
            GatewayOptions {
                max_wait: Duration::from_millis(2),
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let trace = synthetic_trace(SHAPE, 4, 32, 12, 7);
        let responses = replay_blocking(&gw, trace.clone(), 3);
        assert_eq!(responses.len(), 12);
        for (item, resp) in trace.iter().zip(&responses) {
            assert_eq!(resp.len, item.len);
            assert_eq!(resp.out.len(), SHAPE.v_len(item.len));
            assert!(resp.out.iter().all(|x| x.is_finite()));
            assert!(resp.masked, "masking defaults on");
            // blocking replay never routes up: tightest fit always
            let want_bucket = if item.len <= 16 { 16 } else { 32 };
            assert_eq!(resp.bucket_seq_len, want_bucket);
        }
        let m = gw.bucket_metrics();
        let completed: u64 = m.iter()
            .map(|b| b.completed.load(Ordering::Relaxed))
            .sum();
        assert_eq!(completed, 12);
        for b in m {
            if b.completed.load(Ordering::Relaxed) == 0 {
                continue;
            }
            assert!(b.occupancy() >= 1.0);
            let waste = b.padding_waste();
            assert!((0.0..1.0).contains(&waste), "waste {waste}");
            // masked: kernels executed exactly the valid rows
            assert_eq!(b.computed_rows.load(Ordering::Relaxed),
                       b.valid_rows.load(Ordering::Relaxed));
            assert_eq!(b.compute_waste(), 0.0);
            assert!((b.compute_saved() - waste).abs() < 1e-12,
                    "masking saves exactly the padded rows");
            assert!(b.percentile_us(99.0) >= b.percentile_us(50.0));
            assert!(b.valid_rows.load(Ordering::Relaxed) > 0);
        }
        assert_eq!(gw.rejected_total(), 0);
        let report = bucket_report(&gw, 1.0);
        assert_eq!(report.len(), 2);
        assert!(report
            .iter()
            .all(|r| r.len() == BUCKET_REPORT_HEADERS.len()));
        gw.shutdown();
    }

    #[test]
    fn gateway_rejects_overlong_empty_and_malformed() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 2)],
            GatewayOptions::default(),
        )
        .unwrap();
        // over-max: longer than every bucket
        let err = gw
            .submit(block(17, 8, 1), block(17, 8, 2), block(17, 8, 3), 17)
            .unwrap_err();
        assert!(format!("{err}").contains("exceeds every bucket"));
        assert_eq!(gw.overlong_total(), 1);
        assert_eq!(gw.rejected_total(), 1);
        // len 0
        assert!(gw.submit(vec![], vec![], vec![], 0).is_err());
        // shape mismatch
        let err = gw
            .submit(vec![0.0; 3], block(4, 8, 1), block(4, 8, 2), 4)
            .unwrap_err();
        assert!(format!("{err}").contains("shape mismatch"));
        gw.shutdown();
    }

    #[test]
    fn gateway_validates_buckets_at_start() {
        let bad_kernel = ServingGateway::start(
            SHAPE, vec![Bucket::native("no-such-kernel", 16, 2)],
            GatewayOptions::default());
        assert!(bad_kernel.is_err());
        // HLO buckets (empty kernel) don't belong in the gateway
        let hlo = ServingGateway::start(
            SHAPE, vec![Bucket::hlo("asr.forward", 16, 2)],
            GatewayOptions::default());
        assert!(hlo.is_err());
        let zero = ServingGateway::start(
            SHAPE, vec![Bucket::native("full", 0, 2)],
            GatewayOptions::default());
        assert!(zero.is_err());
        let none = ServingGateway::start(SHAPE, vec![],
                                         GatewayOptions::default());
        assert!(none.is_err());
        // causal serving needs a causal-capable kernel in every bucket
        let causal_full = ServingGateway::start(
            SHAPE, vec![Bucket::native("full", 16, 2)],
            GatewayOptions { causal: true, ..GatewayOptions::default() });
        assert!(format!("{}", causal_full.unwrap_err())
            .contains("causal"));
    }

    #[test]
    fn decode_session_replies_match_the_full_recompute_span_for_span() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 32, 2)],
            GatewayOptions {
                max_wait: Duration::from_millis(2),
                seed: 23,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        // one session: prefill 10, steps to 16 and 22; items carry the
        // full history with bit-identical prefixes
        let trace = synthetic_decode_trace(SHAPE, 10, 2, 6, 1, 40);
        assert_eq!(trace.len(), 3);
        let kernel = kernel_by_name("full").unwrap();
        let mut prev_len = 0usize;
        for (step, item) in trace.iter().enumerate() {
            let rx = gw
                .submit_session_blocking(item.q.clone(), item.k.clone(),
                                         item.v.clone(), item.len, 0)
                .unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.session, Some(0));
            assert_eq!(resp.span_start, prev_len);
            assert_eq!(resp.len, item.len);
            assert_eq!(resp.out.len(),
                       SHAPE.heads * (item.len - prev_len) * SHAPE.dv);
            assert_eq!(resp.cache_hit, Some(step > 0),
                       "prefill misses, steps hit");
            let want = session_reference(kernel.as_ref(), SHAPE, 23, 0,
                                         &item.q, &item.k, &item.v,
                                         item.len, prev_len);
            assert!(same_bits(&resp.out, &want),
                    "step {step} diverged from the full recompute");
            prev_len = item.len;
        }
        let m = &gw.bucket_metrics()[0];
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.saved_rows.load(Ordering::Relaxed), (10 + 16) as u64);
        assert!(m.cache_hit_rate() > 0.6);
        assert!(m.recompute_saved() > 0.0);
        // the cache holds the full history under generation 0
        assert_eq!(gw.cache().session_len(
            CacheRef { session: 0, generation: 0 }), Some(22));
        // ending the session drops gateway state and panels
        gw.end_session(0);
        assert_eq!(gw.cache().session_len(
            CacheRef { session: 0, generation: 0 }), None);
        gw.shutdown();
    }

    #[test]
    fn quantized_gateway_decode_is_deterministic_and_within_tolerance() {
        // i8 panels give up bit-identity by design: a hit dequantizes
        // the stored history, so its output may drift from the exact
        // recompute — but only within the tolerance band, and
        // deterministically (two identically configured gateways agree
        // bit for bit).  Misses compute on exact request tensors and
        // stay bit-identical.
        let mk = || {
            ServingGateway::start(
                SHAPE,
                vec![Bucket::native("full", 32, 2)],
                GatewayOptions {
                    max_wait: Duration::from_millis(2),
                    seed: 23,
                    cache_quant: CacheQuant::I8PerPanel,
                    ..GatewayOptions::default()
                },
            )
            .unwrap()
        };
        let (gw, gw2) = (mk(), mk());
        let trace = synthetic_decode_trace(SHAPE, 10, 2, 6, 1, 40);
        let kernel = kernel_by_name("full").unwrap();
        let mut prev_len = 0usize;
        for (step, item) in trace.iter().enumerate() {
            let run = |g: &ServingGateway| {
                g.submit_session_blocking(item.q.clone(), item.k.clone(),
                                          item.v.clone(), item.len, 0)
                    .unwrap()
                    .recv_timeout(Duration::from_secs(30))
                    .unwrap()
            };
            let (resp, resp2) = (run(&gw), run(&gw2));
            assert!(same_bits(&resp.out, &resp2.out),
                    "step {step}: quantized decode must be deterministic");
            assert_eq!(resp.cache_hit, Some(step > 0));
            let want = session_reference(kernel.as_ref(), SHAPE, 23, 0,
                                         &item.q, &item.k, &item.v,
                                         item.len, prev_len);
            assert_eq!(resp.out.len(), want.len());
            for (a, b) in resp.out.iter().zip(&want) {
                let err = (f64::from(*a) - f64::from(*b)).abs();
                assert!(err <= 0.1 + 0.1 * f64::from(*b).abs(),
                        "step {step}: err {err} vs reference {b}");
            }
            if step == 0 {
                assert!(same_bits(&resp.out, &want),
                        "the prefill miss computes on exact inputs");
            }
            prev_len = item.len;
        }
        gw.shutdown();
        gw2.shutdown();
    }

    #[test]
    fn sessions_route_up_when_the_history_outgrows_the_bucket() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 2),
                 Bucket::native("full", 32, 2)],
            GatewayOptions {
                max_wait: Duration::from_millis(2),
                seed: 5,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let trace = synthetic_decode_trace(SHAPE, 12, 1, 8, 1, 41);
        let kernel = kernel_by_name("full").unwrap();
        // prefill (12 rows) pins to the N=16 bucket
        let r0 = gw
            .submit_session_blocking(trace[0].q.clone(),
                                     trace[0].k.clone(),
                                     trace[0].v.clone(), 12, 7)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert_eq!(r0.bucket_seq_len, 16);
        // the grown history (20 rows) routes up to N=32 — and the
        // cache entry migrates with it (the step still hits)
        let r1 = gw
            .submit_session_blocking(trace[1].q.clone(),
                                     trace[1].k.clone(),
                                     trace[1].v.clone(), 20, 7)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert_eq!(r1.bucket_seq_len, 32);
        assert_eq!(r1.cache_hit, Some(true),
                   "route-up must not lose the cached panels");
        let want = session_reference(kernel.as_ref(), SHAPE, 5, 7,
                                     &trace[1].q, &trace[1].k,
                                     &trace[1].v, 20, 12);
        assert!(same_bits(&r1.out, &want),
                "migrated session diverged from the full recompute");
        assert_eq!(gw.bucket_metrics()[1]
                       .session_route_up
                       .load(Ordering::Relaxed), 1);
        gw.shutdown();
    }

    #[test]
    fn session_steps_must_extend_the_history_and_require_masking() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 2)],
            GatewayOptions::default(),
        )
        .unwrap();
        let (q, k, v) = (block(8, 8, 1), block(8, 8, 2), block(8, 8, 3));
        let rx = gw
            .submit_session_blocking(q.clone(), k.clone(), v.clone(), 8,
                                     3)
            .unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        // a repeat of the same length does not extend the history
        let err = gw
            .submit_session_blocking(q.clone(), k.clone(), v.clone(), 8,
                                     3)
            .unwrap_err();
        assert!(format!("{err}").contains("does not extend"));
        // longer than every bucket
        let err = gw
            .submit_session(block(17, 8, 4), block(17, 8, 5),
                            block(17, 8, 6), 17, 9)
            .unwrap_err();
        assert!(format!("{err}").contains("exceeds every bucket"));
        gw.shutdown();
        // an unmasked gateway refuses sessions outright
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 2)],
            GatewayOptions { mask: false, ..GatewayOptions::default() },
        )
        .unwrap();
        let err = gw
            .submit_session(block(8, 8, 1), block(8, 8, 2),
                            block(8, 8, 3), 8, 1)
            .unwrap_err();
        assert!(format!("{err}").contains("masking"));
        gw.shutdown();
    }

    #[test]
    fn session_ttl_sweeps_idle_sessions() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("full", 16, 2)],
            GatewayOptions {
                session_ttl: Some(Duration::from_millis(250)),
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let (q, k, v) = (block(8, 8, 1), block(8, 8, 2), block(8, 8, 3));
        let rx = gw.submit_session_blocking(q, k, v, 8, 3).unwrap();
        let _ = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(gw.live_sessions(), 1);
        assert!(gw.cache().used_rows() > 0,
                "prefill should populate the cache");
        std::thread::sleep(Duration::from_millis(500));
        // the abandoned session (no "end" ever sent) is collected, and
        // its table entry AND cached panels are released
        assert_eq!(gw.sweep_expired(), 1);
        assert_eq!(gw.live_sessions(), 0);
        assert_eq!(gw.cache().used_rows(), 0);
        assert_eq!(gw.sweep_expired(), 0);
        gw.shutdown();
    }

    #[test]
    fn decode_trace_replay_exercises_the_cache_path() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("i-clustered-4", 32, 4)],
            GatewayOptions {
                max_wait: Duration::from_millis(2),
                seed: 31,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        // 3 sessions × (prefill + 2 steps), interleaved with replay
        let trace = synthetic_decode_trace(SHAPE, 8, 2, 4, 3, 42);
        assert_eq!(trace.len(), 9);
        let responses = replay_blocking(&gw, trace.clone(), 2);
        let kernel = kernel_by_name("i-clustered-4").unwrap();
        for (item, resp) in trace.iter().zip(&responses) {
            assert_eq!(resp.session, item.session);
            assert_eq!(resp.len, item.len);
            let want = session_reference(
                kernel.as_ref(), SHAPE, 31, item.session.unwrap(),
                &item.q, &item.k, &item.v, item.len, resp.span_start);
            assert!(same_bits(&resp.out, &want),
                    "session {:?} len {} diverged", item.session,
                    item.len);
        }
        let m = &gw.bucket_metrics()[0];
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 3,
                   "one prefill miss per session");
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 6,
                   "every later step hits");
        let report = bucket_report(&gw, 1.0);
        assert!(report
            .iter()
            .all(|r| r.len() == BUCKET_REPORT_HEADERS.len()));
        gw.shutdown();
    }

    #[test]
    fn causal_linear_sessions_ride_the_recurrent_cache_path() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("linear", 32, 2)],
            GatewayOptions {
                max_wait: Duration::from_millis(2),
                seed: 29,
                causal: true,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        // one session: prefill 10, steps to 16 and 22 — every causal
        // reply must equal the autoregressive full-history recompute,
        // and post-prefill steps must hit the recurrent-state entry
        // (computed rows == the span only: O(1) decode)
        let trace = synthetic_decode_trace(SHAPE, 10, 2, 6, 1, 44);
        let kernel = kernel_by_name("linear").unwrap();
        let mut prev_len = 0usize;
        for (step, item) in trace.iter().enumerate() {
            let rx = gw
                .submit_session_blocking(item.q.clone(), item.k.clone(),
                                         item.v.clone(), item.len, 0)
                .unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.cache_hit, Some(step > 0),
                       "prefill misses, steps hit the recurrent state");
            let want = session_reference_causal(
                kernel.as_ref(), SHAPE, 29, 0, &item.q, &item.k,
                &item.v, item.len, prev_len);
            assert!(same_bits(&resp.out, &want),
                    "causal step {step} diverged from the \
                     autoregressive recompute");
            prev_len = item.len;
        }
        let m = &gw.bucket_metrics()[0];
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        // recurrent hits materialize only the span rows
        assert_eq!(m.saved_rows.load(Ordering::Relaxed), (10 + 16) as u64);
        // the recurrent entry's charge is constant and tiny — far below
        // the 22 rows a panel entry for this history would pin
        assert!(gw.cache().used_rows() > 0 && gw.cache().used_rows() < 22);
        gw.end_session(0);
        assert_eq!(gw.cache().used_rows(), 0);
        gw.shutdown();
    }

    #[test]
    fn causal_one_shot_requests_match_the_causal_unpadded_reference() {
        let gw = ServingGateway::start(
            SHAPE,
            vec![Bucket::native("linear", 32, 2)],
            GatewayOptions {
                max_wait: Duration::from_secs(10),
                workers: 4,
                seed: 13,
                causal: true,
                ..GatewayOptions::default()
            },
        )
        .unwrap();
        let (l0, l1) = (20, 32);
        let (q0, k0, v0) =
            (block(l0, 8, 1), block(l0, 8, 2), block(l0, 8, 3));
        let (q1, k1, v1) =
            (block(l1, 8, 4), block(l1, 8, 5), block(l1, 8, 6));
        let rx0 = gw
            .submit_blocking(q0.clone(), k0.clone(), v0.clone(), l0)
            .unwrap();
        let rx1 = gw
            .submit_blocking(q1.clone(), k1.clone(), v1.clone(), l1)
            .unwrap();
        let r0 = rx0.recv_timeout(Duration::from_secs(30)).unwrap();
        let r1 = rx1.recv_timeout(Duration::from_secs(30)).unwrap();
        let kernel = kernel_by_name("linear").unwrap();
        let u0 = unpadded_reference_causal(kernel.as_ref(), SHAPE, 13, 0,
                                           &q0, &k0, &v0, l0);
        let u1 = unpadded_reference_causal(kernel.as_ref(), SHAPE, 13, 1,
                                           &q1, &k1, &v1, l1);
        assert!(same_bits(&r0.out, &u0),
                "causal masked response != causal unpadded (slot 0)");
        assert!(same_bits(&r1.out, &u1),
                "causal masked response != causal unpadded (slot 1)");
        gw.shutdown();
    }

    #[test]
    fn unpadded_reference_rejects_malformed_blocks() {
        let kernel = kernel_by_name("full").unwrap();
        let ok = unpadded_reference(kernel.as_ref(), SHAPE, 0, 0,
                                    &block(4, 8, 1), &block(4, 8, 2),
                                    &block(4, 8, 3), 4);
        assert_eq!(ok.len(), SHAPE.v_len(4));
        let bad = std::panic::catch_unwind(|| {
            unpadded_reference(kernel_by_name("full").unwrap().as_ref(),
                               SHAPE, 0, 0, &[0.0; 3], &block(4, 8, 2),
                               &block(4, 8, 3), 4)
        });
        assert!(bad.is_err());
    }
}
