//! Training driver: executes compiled train-step HLO in a loop with loss
//! tracking, plateau-based early stopping and checkpointing.  This is the
//! path every paper experiment trains through — Python never runs here.
//!
//! ct-lint: allow(det-entropy, reason = "Instant::now times training steps for throughput logs; optimisation math is driven by compiled HLO, not the clock")

use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::Split;
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::{HostTensor, Runtime};

use super::datafeed::DataFeed;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// total optimizer steps
    pub steps: u64,
    /// validation-loss check cadence (steps)
    pub eval_every: u64,
    /// stop after this many evals without improvement (0 = never)
    pub patience: u64,
    /// number of validation batches averaged per eval
    pub eval_batches: u64,
    /// data + in-graph randomness seed
    pub seed: u64,
    /// print progress lines
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        Self { steps: 400, eval_every: 50, patience: 0, eval_batches: 4,
               seed: 0, verbose: true }
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    /// (step, train loss) samples
    pub losses: Vec<(u64, f32)>,
    /// (step, validation loss) samples
    pub val_losses: Vec<(u64, f32)>,
    pub wall_seconds: f64,
    pub seconds_per_step: f64,
    pub steps_run: u64,
    pub final_loss: f32,
    pub best_val_loss: f32,
}

/// Train `<model>` (manifest name without the `.train` suffix) from
/// scratch; returns the checkpoint at the best validation loss.
pub fn train_model(rt: &Runtime, model: &str, opts: &TrainOptions)
                   -> Result<(Checkpoint, TrainResult)> {
    let init = rt.load(&format!("{model}.init"))?;
    let step_exe = rt.load(&format!("{model}.train"))?;
    let feed = DataFeed::for_program(&step_exe.program, opts.seed)?;
    let batch_size = step_exe.program.batch_size();

    // init: seed -> (params, m, v, step)
    let mut state = init.run(&[HostTensor::scalar_i32(opts.seed as i32)])?;
    if state.len() != 4 {
        bail!("init returned {} outputs, want 4", state.len());
    }

    let mut result = TrainResult {
        losses: Vec::new(),
        val_losses: Vec::new(),
        wall_seconds: 0.0,
        seconds_per_step: 0.0,
        steps_run: 0,
        final_loss: f32::NAN,
        best_val_loss: f32::INFINITY,
    };
    let mut best_params: Option<(Vec<f32>, Vec<f32>, Vec<f32>, i32)> = None;
    let mut evals_since_best = 0u64;
    let t0 = Instant::now();

    for step in 0..opts.steps {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(9);
        // state order: params, m, v, step
        inputs.extend(state.iter().cloned());
        inputs.push(HostTensor::scalar_i32(
            (opts.seed as i32).wrapping_add(step as i32)));
        inputs.extend(feed.batch(Split::Train, step, batch_size));
        let mut out = step_exe.run(&inputs)?;
        let loss = out.pop().unwrap().scalar_f32_value()?;
        state = out; // params, m, v, step
        result.losses.push((step, loss));
        result.final_loss = loss;
        if !loss.is_finite() {
            bail!("{model}: loss diverged at step {step}");
        }

        let is_eval = (step + 1) % opts.eval_every == 0
            || step + 1 == opts.steps;
        if is_eval {
            let val = validation_loss(rt, model, &state[0], &feed,
                                      opts.eval_batches, opts.seed)?;
            result.val_losses.push((step, val));
            if opts.verbose {
                log::info!(
                    "{model} step {:>5} train {:8.4} val {:8.4} ({:.2}s)",
                    step + 1, loss, val, t0.elapsed().as_secs_f64());
            }
            if val < result.best_val_loss {
                result.best_val_loss = val;
                evals_since_best = 0;
                best_params = Some((
                    state[0].as_f32()?.to_vec(),
                    state[1].as_f32()?.to_vec(),
                    state[2].as_f32()?.to_vec(),
                    state[3].as_i32()?[0],
                ));
            } else {
                evals_since_best += 1;
                if opts.patience > 0 && evals_since_best >= opts.patience {
                    result.steps_run = step + 1;
                    break;
                }
            }
        }
        result.steps_run = step + 1;
    }

    result.wall_seconds = t0.elapsed().as_secs_f64();
    result.seconds_per_step =
        result.wall_seconds / result.steps_run.max(1) as f64;

    let (params, m, v, step) = match best_params {
        Some(t) => t,
        None => (
            state[0].as_f32()?.to_vec(),
            state[1].as_f32()?.to_vec(),
            state[2].as_f32()?.to_vec(),
            state[3].as_i32()?[0],
        ),
    };
    let mut ckpt = Checkpoint::fresh(model, params, m, v);
    ckpt.step = step;
    Ok((ckpt, result))
}

/// Mean train-program loss over held-out batches, via the `.train`
/// program's loss output?  No — evaluation must not update parameters, so
/// we run the forward program when a dedicated eval is unavailable.  We
/// approximate validation loss with the train-step loss computed from a
/// *throwaway* state copy (parameters are cloned; updates discarded).
fn validation_loss(rt: &Runtime, model: &str, params: &HostTensor,
                   feed: &DataFeed, batches: u64, seed: u64) -> Result<f32> {
    let step_exe = rt.load(&format!("{model}.train"))?;
    let batch_size = step_exe.program.batch_size();
    let n = params.len();
    let zeros = HostTensor::F32(vec![0.0; n]);
    let mut total = 0f32;
    for i in 0..batches {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(9);
        inputs.push(params.clone());
        inputs.push(zeros.clone());
        inputs.push(zeros.clone());
        inputs.push(HostTensor::scalar_i32(0));
        inputs.push(HostTensor::scalar_i32((seed as i32) ^ 0x5eed));
        inputs.extend(feed.batch(Split::Valid, i, batch_size));
        let out = step_exe.run(&inputs)?;
        total += out.last().unwrap().scalar_f32_value()?;
    }
    Ok(total / batches.max(1) as f32)
}

/// Run a forward program over `batches` held-out batches; returns the
/// concatenated logits and the batches used (for metric computation).
pub fn forward_eval(rt: &Runtime, forward_prog: &str, params: &[f32],
                    feed: &DataFeed, split: Split, batches: u64, seed: u64)
                    -> Result<Vec<(Vec<HostTensor>, Vec<f32>)>> {
    let exe = rt.load(forward_prog)?;
    let batch_size = exe.program.batch_size();
    let mut out = Vec::new();
    for i in 0..batches {
        let batch = feed.batch(split, i, batch_size);
        let mut inputs: Vec<HostTensor> = Vec::new();
        inputs.push(HostTensor::F32(params.to_vec()));
        inputs.extend(feed.forward_inputs(split, i, batch_size));
        inputs.push(HostTensor::scalar_i32((seed as i32) ^ 0x0e7a));
        let mut res = exe.run(&inputs)?;
        let logits = res.remove(0).into_f32()?;
        out.push((batch, logits));
    }
    Ok(out)
}

/// Task metric over `forward_eval` results, matching the paper's
/// reporting: PER% (ctc), masked accuracy (tok), accuracy (cls), F1
/// (span).  Returns `(metric_name, value, human_summary)` via [`Score`].
#[derive(Debug, Clone)]
pub struct Score {
    pub metric: &'static str,
    pub value: f64,
    /// true when higher is better
    pub ascending: bool,
}

impl std::fmt::Display for Score {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} = {:.4}", self.metric, self.value)
    }
}

pub fn score(program: &crate::runtime::Program, _feed: &DataFeed,
             evals: &[(Vec<HostTensor>, Vec<f32>)]) -> Result<Score> {
    use crate::data::asr::ctc_greedy_decode;
    use crate::data::copy_task;
    use crate::metrics::{span_f1, Accuracy, ErrorRate};

    let n = program.seq_len();
    let b = program.batch_size();
    let task = program.config.get("task").as_str().unwrap_or("").to_string();
    match task.as_str() {
        "ctc" => {
            let vocab = program.config.get("out_dim").as_usize().unwrap_or(0);
            let lmax = program.config.get("max_labels").as_usize()
                .unwrap_or(0);
            let mut er = ErrorRate::default();
            for (batch, logits) in evals {
                let xlen = batch[1].as_i32()?;
                let y = batch[2].as_i32()?;
                let ylen = batch[3].as_i32()?;
                for s in 0..b {
                    let rows = &logits[s * n * vocab..(s + 1) * n * vocab];
                    let hyp = ctc_greedy_decode(rows, xlen[s] as usize,
                                                vocab);
                    let gold =
                        &y[s * lmax..s * lmax + ylen[s] as usize];
                    er.add(&hyp, gold);
                }
            }
            Ok(Score { metric: "PER%", value: er.percent(),
                       ascending: false })
        }
        "tok" => {
            let vocab = program.config.get("out_dim").as_usize().unwrap_or(0);
            let mut acc_sum = 0.0;
            for (batch, logits) in evals {
                let cb = copy_task::CopyBatch {
                    x: batch[0].as_i32()?.to_vec(),
                    y: batch[1].as_i32()?.to_vec(),
                    w: batch[2].as_f32()?.to_vec(),
                    batch: b,
                    seq_len: n,
                };
                acc_sum += copy_task::masked_accuracy(&cb, logits, vocab);
            }
            Ok(Score { metric: "accuracy", value: acc_sum
                       / evals.len().max(1) as f64, ascending: true })
        }
        "cls" => {
            let ncls = program.config.get("out_dim").as_usize().unwrap_or(2);
            let mut acc = Accuracy::default();
            for (batch, logits) in evals {
                let y = batch[2].as_i32()?;
                for s in 0..b {
                    let row = &logits[s * ncls..(s + 1) * ncls];
                    let pred = row.iter().enumerate()
                        .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                        .unwrap().0 as i32;
                    acc.add(pred, y[s]);
                }
            }
            Ok(Score { metric: "accuracy", value: acc.value(),
                       ascending: true })
        }
        "span" => {
            let mut total = 0.0;
            let mut count = 0usize;
            for (batch, logits) in evals {
                let ys = batch[2].as_i32()?;
                let ye = batch[3].as_i32()?;
                for s in 0..b {
                    // logits (B, N, 2): channel 0 start, channel 1 end
                    let rows = &logits[s * n * 2..(s + 1) * n * 2];
                    let argmax = |ch: usize| rows
                        .chunks_exact(2)
                        .map(|p| p[ch])
                        .enumerate()
                        .max_by(|a, c| a.1.partial_cmp(&c.1).unwrap())
                        .unwrap().0 as i32;
                    total += span_f1((argmax(0), argmax(1)),
                                     (ys[s], ye[s]));
                    count += 1;
                }
            }
            Ok(Score { metric: "F1", value: total / count.max(1) as f64,
                       ascending: true })
        }
        other => bail!("no metric for task {other:?}"),
    }
}
