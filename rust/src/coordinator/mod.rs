//! Layer-3 coordinator — the serving/training control plane.
//!
//! The paper's contribution is an attention approximation, so L3 is the
//! machinery that makes it deployable: a training driver that executes
//! compiled train-step HLO in a loop with convergence tracking, and a
//! serving engine with length-bucket routing, deadline-based dynamic
//! batching, a worker pool and backpressure (vLLM-router-shaped, scaled
//! to one host).

pub mod batcher;
pub mod datafeed;
pub mod router;
pub mod serve;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher};
pub use datafeed::DataFeed;
pub use router::Router;
pub use serve::{AttnRequest, AttnResponse, AttnShape, InferenceEngine,
                NativeAttentionEngine, NativeAttnOptions, Request,
                Response, ServeOptions};
pub use trainer::{train_model, TrainOptions, TrainResult};
