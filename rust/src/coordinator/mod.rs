//! ct-contract: panic-free
//!
//! Layer-3 coordinator — the serving/training control plane.
//!
//! The paper's contribution is an attention approximation, so L3 is the
//! machinery that makes it deployable: a training driver that executes
//! compiled train-step HLO in a loop with convergence tracking, and two
//! serving stacks built on the same length-bucket router, deadline
//! batcher and backpressure substrate (vLLM-router-shaped, scaled to one
//! host):
//!
//! - [`InferenceEngine`] — compiled-HLO buckets through PJRT, masking
//!   ragged lengths inside the graph via the `xlen` input;
//! - [`ServingGateway`] — a fleet of native attention engines, one
//!   kernel/pad-length/batch-size [`Bucket`] each, sharing one worker
//!   budget, with route-up admission control, valid-length masking
//!   (responses are bit-identical to the unpadded computation),
//!   session-aware incremental decode (a gateway-global
//!   `attention::KvCache` behind `attention::CachingBackend`; sessions
//!   pin to buckets and route up as they grow), idle-session TTL
//!   eviction, and per-bucket [`BucketMetrics`] (see
//!   `docs/SERVING.md`).  With `GatewayOptions::shards` set, every
//!   bucket executes through an `attention::ShardedBackend` fan-out,
//!   and [`HashRing`] (this module's `ring`) keeps each decode session
//!   on its owning shard worker.
//!
//! Both stacks consume the same request information — tensors plus true
//! lengths — and the native side resolves it through the
//! `attention::AttnBatch` descriptor and the `attention::AttentionBackend`
//! execution seam.

pub mod batcher;
pub mod datafeed;
pub mod gateway;
pub mod ring;
pub mod router;
pub mod serve;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher};
pub use datafeed::DataFeed;
pub use gateway::{bucket_report, pad_batch, replay_blocking,
                  session_reference, session_reference_causal, span_rows,
                  synthetic_decode_trace, synthetic_trace,
                  unpadded_reference, unpadded_reference_causal,
                  valid_rows, BucketMetrics, GatewayOptions,
                  GatewayRequest, GatewayResponse, GatewayShape,
                  ServingGateway, TraceItem, BUCKET_REPORT_HEADERS};
pub use ring::HashRing;
pub use router::{Bucket, Router};
pub use serve::{AttnRequest, AttnResponse, AttnShape, InferenceEngine,
                NativeAttentionEngine, NativeAttnOptions, Request,
                Response, ServeOptions};
pub use trainer::{train_model, TrainOptions, TrainResult};
