//! ct-contract: panic-free
//!
//! Consistent-hash ring: stable session → shard placement for the
//! multi-host gateway.
//!
//! [`HashRing`] places `vnodes` virtual points per shard id on a u64
//! circle (SplitMix64 over an FNV-1a digest of the id — fully
//! deterministic from the id set alone, no RNG state, no insertion-order
//! dependence) and a session key is owned by the first point clockwise
//! from its own hash.  That gives the three properties the sharded
//! serving path needs, each pinned by a test below:
//!
//! - **determinism** — the same shard ids (in any order) always build
//!   the same ring, so every gateway replica routes identically;
//! - **stickiness** — removing a shard moves only the sessions it
//!   owned, adding one only steals its fair share; every other session
//!   keeps its owner, so `attention::KvCache` state stays where it is
//!   across membership changes;
//! - **balance** — with enough virtual nodes, ownership spreads within
//!   a constant factor of fair share.
//!
//! The ring answers *placement* only; liveness is the caller's problem
//! (`attention::sharded::ShardedBackend` keeps a down-map next to its
//! ring and falls back to local compute for sessions whose owner is
//! unreachable — ownership itself never flaps).

use crate::prng::SplitMix64;

/// FNV-1a over the shard id bytes — the stable string → u64 digest the
/// virtual-node stream is seeded from.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Deterministic consistent-hash ring over string shard ids.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Sorted, deduplicated shard ids — the canonical member set.
    ids: Vec<String>,
    /// `(point, index into ids)`, sorted by point (ties by index, which
    /// the sort order makes deterministic too).
    points: Vec<(u64, usize)>,
    vnodes: usize,
}

impl HashRing {
    /// Virtual nodes per shard when the caller has no opinion — enough
    /// for ~±10% share balance at small fleet sizes.
    pub const DEFAULT_VNODES: usize = 64;

    /// Build the ring for `ids` (order-insensitive; duplicates are
    /// collapsed).  `vnodes` is clamped to at least 1.
    pub fn new(ids: &[String], vnodes: usize) -> Self {
        let vnodes = vnodes.max(1);
        let mut ids = ids.to_vec();
        ids.sort();
        ids.dedup();
        let mut points = Vec::with_capacity(ids.len() * vnodes);
        for (i, id) in ids.iter().enumerate() {
            let mut sm = SplitMix64::new(fnv1a(id.as_bytes()));
            for _ in 0..vnodes {
                points.push((sm.next_u64(), i));
            }
        }
        points.sort_unstable();
        Self { ids, points, vnodes }
    }

    /// Member count.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The canonical (sorted) member ids.
    pub fn ids(&self) -> &[String] {
        &self.ids
    }

    /// Index (into [`HashRing::ids`]) of the shard owning `key` —
    /// `None` only on an empty ring.  Keys are mixed through SplitMix64
    /// first, so dense session ids (1, 2, 3, …) spread uniformly.
    pub fn owner(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = SplitMix64::new(key).next_u64();
        let i = self.points.partition_point(|&(p, _)| p < h);
        let i = if i == self.points.len() { 0 } else { i };
        // ct-lint: allow(panic-index, reason = "i < points.len() by the wrap-around guard on the previous line, and points is non-empty past the early return")
        Some(self.points[i].1)
    }

    /// Id of the shard owning `key`.
    pub fn owner_id(&self, key: u64) -> Option<&str> {
        // ct-lint: allow(panic-index, reason = "owner() only yields indices minted from ids when the ring was built")
        self.owner(key).map(|i| self.ids[i].as_str())
    }

    /// A new ring with `id` added (same vnodes) — membership changes
    /// build fresh rings; nothing mutates in place.
    pub fn with_shard(&self, id: &str) -> Self {
        let mut ids = self.ids.clone();
        ids.push(id.to_string());
        Self::new(&ids, self.vnodes)
    }

    /// A new ring with `id` removed (same vnodes).
    pub fn without_shard(&self, id: &str) -> Self {
        let ids: Vec<String> =
            self.ids.iter().filter(|x| x.as_str() != id).cloned().collect();
        Self::new(&ids, self.vnodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("shard-{i}")).collect()
    }

    #[test]
    fn construction_is_deterministic_and_order_independent() {
        let a = HashRing::new(&ids(5), 32);
        let mut rev = ids(5);
        rev.reverse();
        let b = HashRing::new(&rev, 32);
        let c = HashRing::new(&ids(5), 32);
        for key in 0..1000u64 {
            assert_eq!(a.owner_id(key), b.owner_id(key),
                       "insertion order changed placement of {key}");
            assert_eq!(a.owner(key), c.owner(key),
                       "rebuild changed placement of {key}");
        }
    }

    #[test]
    fn duplicate_ids_collapse() {
        let mut doubled = ids(3);
        doubled.extend(ids(3));
        let ring = HashRing::new(&doubled, 16);
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.ids(), &ids(3)[..]);
    }

    #[test]
    fn removal_only_moves_the_removed_shards_sessions() {
        let full = HashRing::new(&ids(4), 64);
        let reduced = full.without_shard("shard-2");
        let total = 4000u64;
        let mut moved = 0usize;
        for key in 0..total {
            let before = full.owner_id(key).unwrap();
            let after = reduced.owner_id(key).unwrap();
            if before == "shard-2" {
                assert_ne!(after, "shard-2");
                moved += 1;
            } else {
                // stickiness: sessions on surviving shards never move
                assert_eq!(before, after, "session {key} moved off a \
                                           surviving shard");
            }
        }
        // the rebalanced fraction is the removed shard's share — about
        // 1/4, and certainly nowhere near a full reshuffle
        let frac = moved as f64 / total as f64;
        assert!(frac > 0.05 && frac < 0.5,
                "removal moved {frac} of sessions");
    }

    #[test]
    fn addition_only_steals_for_the_new_shard() {
        let base = HashRing::new(&ids(3), 64);
        let grown = base.with_shard("shard-3");
        let total = 4000u64;
        let mut stolen = 0usize;
        for key in 0..total {
            let before = base.owner_id(key).unwrap().to_string();
            let after = grown.owner_id(key).unwrap();
            if after != before {
                // every moved session lands on the new shard only
                assert_eq!(after, "shard-3",
                           "session {key} moved between old shards");
                stolen += 1;
            }
        }
        // the new shard takes roughly its fair share (1/4)
        let frac = stolen as f64 / total as f64;
        assert!(frac > 0.05 && frac < 0.6, "addition stole {frac}");
    }

    #[test]
    fn ownership_is_roughly_balanced() {
        let ring = HashRing::new(&ids(4), 128);
        let total = 8000u64;
        let mut counts = [0usize; 4];
        for key in 0..total {
            counts[ring.owner(key).unwrap()] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let share = c as f64 / total as f64;
            assert!(share > 0.10 && share < 0.45,
                    "shard {i} owns {share} of the keyspace");
        }
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let ring = HashRing::new(&[], 16);
        assert!(ring.is_empty());
        assert_eq!(ring.owner(9), None);
        assert_eq!(ring.owner_id(9), None);
    }
}
