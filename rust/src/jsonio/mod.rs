//! Minimal JSON substrate (serde is unavailable offline — DESIGN.md §5).
//!
//! A small, total parser + writer over an owned [`Value`] tree. Covers the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); numbers are kept as `f64` which is lossless for every
//! integer the manifest contains (< 2^53).
//!
//! **Byte-stability contract.** Serialisation is deterministic: objects
//! emit fields in insertion order ([`ObjMap`] preserves it; the parser
//! inserts in document order, so parse → write round-trips field order),
//! and the number writer emits integers exactly.  Building the same
//! document twice — or parsing and re-writing it — yields identical
//! bytes, which is what lets oracle fixtures and `oracle-report.json`
//! diff cleanly in git.  [`to_string_pretty`] is the stable multi-line
//! form used for checked-in files.

use std::fmt;

/// An insertion-order-preserving string-keyed map for [`Value::Obj`].
///
/// JSON writers that sort keys scramble the author's field order and make
/// semantically-identical documents diff noisily; hash maps are worse
/// (nondeterministic).  This is a small Vec-backed map — objects in our
/// manifests have at most a few dozen fields, so linear `get` is fine —
/// with last-insert-wins replacement *in place* (the key keeps its
/// original position), so output order is a pure function of the build
/// order.
#[derive(Debug, Clone, Default)]
pub struct ObjMap {
    entries: Vec<(String, Value)>,
}

impl ObjMap {
    pub fn new() -> Self {
        Self { entries: Vec::new() }
    }
    /// Insert, replacing any existing value for `key` in place.
    pub fn insert(&mut self, key: String, value: Value) {
        match self.entries.iter_mut().find(|(k, _)| *k == key) {
            Some(slot) => slot.1 = value,
            None => self.entries.push((key, value)),
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.entries.iter().map(|(k, v)| (k, v))
    }
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.iter().map(|(k, _)| k)
    }
}

impl FromIterator<(String, Value)> for ObjMap {
    fn from_iter<I: IntoIterator<Item = (String, Value)>>(it: I) -> Self {
        let mut m = ObjMap::new();
        for (k, v) in it {
            m.insert(k, v);
        }
        m
    }
}

impl<'a> IntoIterator for &'a ObjMap {
    type Item = (&'a String, &'a Value);
    type IntoIter = std::iter::Map<
        std::slice::Iter<'a, (String, Value)>,
        fn(&'a (String, Value)) -> (&'a String, &'a Value),
    >;
    fn into_iter(self) -> Self::IntoIter {
        self.entries.iter().map(|(k, v)| (k, v))
    }
}

/// Order-insensitive equality: two objects are equal iff they hold the
/// same key→value set, matching JSON semantics (field order is a
/// serialisation detail, not data).
impl PartialEq for ObjMap {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len()
            && self.iter().all(|(k, v)| other.get(k) == Some(v))
    }
}

/// An owned JSON document node.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(ObjMap),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&ObjMap> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Value::Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Mutable field access on an object; `None` for non-objects or
    /// missing keys.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Obj(o) => {
                o.entries.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }
    /// Insert/replace a field on an object; no-op on non-objects.
    pub fn set(&mut self, key: &str, value: Value) {
        if let Value::Obj(o) = self {
            o.insert(key.to_string(), value);
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a [`Value::Obj`] from key/value pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { at: self.i, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(ParseError { at: start, msg: "bad number".into() })
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(ParseError {
                        at: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| ParseError {
                                        at: self.i,
                                        msg: "bad \\u escape".into(),
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| ParseError {
                                    at: self.i,
                                    msg: "bad \\u escape".into(),
                                },
                            )?;
                            self.i += 4;
                            // BMP only; surrogate pairs are not present in
                            // our manifests — map them to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| ParseError {
                            at: self.i,
                            msg: "invalid utf8".into(),
                        })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = ObjMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// writing
// ---------------------------------------------------------------------------

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Emit a number so that re-parsing the text recovers the exact f64
/// bits: integers in i64 range print without a fraction, and every
/// other finite value uses Rust's shortest-round-trip f64 display —
/// tolerance knobs like `"rel_tol": 0.15` must survive a report
/// rewrite byte-stably (`docs/TESTING.md`).  JSON has no non-finite
/// literals, so NaN/±inf degrade to `null` instead of emitting
/// unparseable text, and negative zero keeps its sign bit.
fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n == 0.0 && n.is_sign_negative() {
        out.push_str("-0.0");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_escaped(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

/// Serialise to compact JSON text.
pub fn to_string(v: &Value) -> String {
    v.to_string()
}

fn write_pretty(v: &Value, indent: usize, out: &mut String) {
    const STEP: usize = 2;
    match v {
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(indent + STEP));
                write_pretty(item, indent + STEP, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(indent));
            out.push(']');
        }
        Value::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&" ".repeat(indent + STEP));
                write_escaped(k, out);
                out.push_str(": ");
                write_pretty(val, indent + STEP, out);
            }
            out.push('\n');
            out.push_str(&" ".repeat(indent));
            out.push('}');
        }
        other => write_value(other, out),
    }
}

/// Serialise to stable multi-line JSON (2-space indent, field order
/// preserved, trailing newline) — the form for checked-in files like
/// oracle fixture headers and `oracle-report.json`, so regenerating an
/// unchanged document is byte-identical and git diffs stay line-scoped.
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_pretty(v, 0, &mut s);
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e1").unwrap(), Value::Num(-125.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_bool(),
                   Some(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"he\"llo\n","n":-3}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn floats_round_trip_byte_stably_at_full_precision() {
        // tolerance knobs (abs_tol/rel_tol) must survive a
        // parse→rewrite cycle byte-for-byte: the emitter uses f64
        // shortest-round-trip display, so text → bits → text is a
        // fixed point for any finite decimal
        for src in ["0.15", "0.05", "1e-5", "0.00345",
                    "0.1000000000000001", "2.2250738585072014e-308",
                    "-0.0"] {
            let v = parse(src).unwrap();
            let emitted = to_string(&v);
            let back = parse(&emitted).unwrap();
            assert_eq!(to_string(&back), emitted, "{src}");
            // and the f64 bits themselves are preserved
            let (a, b) = (v.as_f64().unwrap(), back.as_f64().unwrap());
            assert_eq!(a.to_bits(), b.to_bits(), "{src}");
        }
    }

    #[test]
    fn non_finite_floats_degrade_to_null() {
        // JSON has no NaN/inf literals; emitting them would poison the
        // document for every parser (ours included)
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = to_string(&Value::Num(bad));
            assert_eq!(text, "null");
            assert_eq!(parse(&text).unwrap(), Value::Null);
        }
    }

    #[test]
    fn integers_survive_roundtrip_exactly() {
        let v = parse("123456789012").unwrap();
        assert_eq!(to_string(&v), "123456789012");
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Value::Null);
    }

    #[test]
    fn objects_emit_fields_in_insertion_order() {
        let v = obj(vec![("zeta", 1.0.into()),
                         ("alpha", 2.0.into()),
                         ("mid", Value::Null)]);
        assert_eq!(to_string(&v), r#"{"zeta":1,"alpha":2,"mid":null}"#);
    }

    #[test]
    fn parse_rewrite_preserves_document_field_order() {
        let src = r#"{"z":1,"a":{"y":2,"b":3},"m":[{"k":4,"c":5}]}"#;
        assert_eq!(to_string(&parse(src).unwrap()), src);
    }

    #[test]
    fn serialization_is_byte_stable_across_builds() {
        let build = || {
            obj(vec![
                ("name", "fixture".into()),
                ("version", 1usize.into()),
                ("items", Value::Arr(vec![
                    obj(vec![("len", 5usize.into()), ("ok", true.into())]),
                    obj(vec![("len", 9usize.into()), ("ok", false.into())]),
                ])),
            ])
        };
        assert_eq!(to_string(&build()), to_string(&build()));
        assert_eq!(to_string_pretty(&build()), to_string_pretty(&build()));
        // and a parse → write cycle of the pretty form is stable too
        let pretty = to_string_pretty(&build());
        assert_eq!(to_string_pretty(&parse(&pretty).unwrap()), pretty);
    }

    #[test]
    fn duplicate_key_last_wins_in_place() {
        let mut m = ObjMap::new();
        m.insert("a".into(), 1.0.into());
        m.insert("b".into(), 2.0.into());
        m.insert("a".into(), 3.0.into());
        assert_eq!(to_string(&Value::Obj(m)), r#"{"a":3,"b":2}"#);
    }

    #[test]
    fn object_equality_is_order_insensitive() {
        let a = parse(r#"{"x":1,"y":[2,3]}"#).unwrap();
        let b = parse(r#"{"y":[2,3],"x":1}"#).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, parse(r#"{"x":1,"y":[3,2]}"#).unwrap());
        assert_ne!(a, parse(r#"{"x":1}"#).unwrap());
    }

    #[test]
    fn pretty_form_parses_back_equal() {
        let v = parse(r#"{"a":[1,{"b":[]},{}],"c":"s"}"#).unwrap();
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn set_and_get_mut_edit_objects() {
        let mut v = parse(r#"{"a":1}"#).unwrap();
        v.set("b", true.into());
        *v.get_mut("a").unwrap() = 7.0.into();
        assert_eq!(to_string(&v), r#"{"a":7,"b":true}"#);
    }
}
