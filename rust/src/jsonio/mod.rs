//! Minimal JSON substrate (serde is unavailable offline — DESIGN.md §5).
//!
//! A small, total parser + writer over an owned [`Value`] tree. Covers the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); numbers are kept as `f64` which is lossless for every
//! integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// An owned JSON document node.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Value::Null` for anything that isn't there.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a [`Value::Obj`] from key/value pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { at: self.i, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(ParseError { at: start, msg: "bad number".into() })
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(ParseError {
                        at: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| ParseError {
                                        at: self.i,
                                        msg: "bad \\u escape".into(),
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(
                                |_| ParseError {
                                    at: self.i,
                                    msg: "bad \\u escape".into(),
                                },
                            )?;
                            self.i += 4;
                            // BMP only; surrogate pairs are not present in
                            // our manifests — map them to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| ParseError {
                            at: self.i,
                            msg: "invalid utf8".into(),
                        })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected , or }"),
            }
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// writing
// ---------------------------------------------------------------------------

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_escaped(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

/// Serialise to compact JSON text.
pub fn to_string(v: &Value) -> String {
    v.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e1").unwrap(), Value::Num(-125.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_bool(),
                   Some(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"he\"llo\n","n":-3}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn integers_survive_roundtrip_exactly() {
        let v = parse("123456789012").unwrap();
        assert_eq!(to_string(&v), "123456789012");
    }

    #[test]
    fn get_on_missing_returns_null() {
        let v = parse("{}").unwrap();
        assert_eq!(*v.get("nope"), Value::Null);
    }
}
