//! Scoped data-parallel worker pool for the batched attention engine.
//!
//! Std-only (`std::thread::scope`), no queues or long-lived threads: a
//! [`WorkerPool`] is just a worker-count policy, and each `map_indexed`
//! call spawns scoped workers that claim slice indices from an atomic
//! counter.  Results are gathered per worker and scattered back in index
//! order, so the output `Vec` is **independent of thread scheduling** —
//! combined with per-slice PRNG streams (`prng::slice_stream`) this makes
//! parallel kernel output bit-identical to a sequential loop.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-count policy for scoped data-parallel maps.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// One worker: runs inline on the caller thread.
    pub fn sequential() -> Self {
        Self { workers: 1 }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        Self::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Compute `f(i)` for `i in 0..n` and return results in index order.
    ///
    /// Work is claimed dynamically (atomic counter), results are written
    /// back by index, so the output is deterministic regardless of how
    /// the scheduler interleaves workers.
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.workers == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.workers.min(n);
        let next = AtomicUsize::new(0);
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("pool worker panicked") {
                    out[i] = Some(r);
                }
            }
        });
        out.into_iter()
            .map(|r| r.expect("pool missed an index"))
            .collect()
    }

    /// Zip `f(i, chunk_i)` over pre-split disjoint mutable chunks (e.g.
    /// `BatchMatrix::slices_mut`), claiming indices dynamically.
    pub fn for_each_mut<T, F>(&self, chunks: Vec<&mut T>, f: F)
    where
        T: Send + ?Sized,
        F: Fn(usize, &mut T) + Sync,
    {
        if self.workers == 1 || chunks.len() <= 1 {
            for (i, c) in chunks.into_iter().enumerate() {
                f(i, c);
            }
            return;
        }
        let n = chunks.len();
        let workers = self.workers.min(n);
        let next = AtomicUsize::new(0);
        // hand each chunk its own cell so workers can claim arbitrary
        // indices without aliasing
        let cells: Vec<std::sync::Mutex<Option<&mut T>>> = chunks
            .into_iter()
            .map(|c| std::sync::Mutex::new(Some(c)))
            .collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let chunk = cells[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("chunk claimed twice");
                    f(i, chunk);
                });
            }
        });
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_indexed_returns_results_in_index_order() {
        let pool = WorkerPool::new(4);
        let got = pool.map_indexed(100, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn map_indexed_matches_sequential_for_any_worker_count() {
        for w in [1, 2, 3, 8, 64] {
            let got = WorkerPool::new(w).map_indexed(17, |i| 3 * i + 1);
            let want = WorkerPool::sequential().map_indexed(17, |i| 3 * i + 1);
            assert_eq!(got, want, "workers={w}");
        }
    }

    #[test]
    fn map_indexed_runs_each_index_exactly_once() {
        let hits: Vec<AtomicUsize> =
            (0..37).map(|_| AtomicUsize::new(0)).collect();
        WorkerPool::new(5).map_indexed(37, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_indexed_empty_and_single() {
        let pool = WorkerPool::new(8);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn for_each_mut_writes_every_chunk() {
        let mut data = vec![0f32; 6 * 4];
        let chunks: Vec<&mut [f32]> = data.chunks_mut(4).collect();
        WorkerPool::new(3).for_each_mut(chunks, |i, c| {
            c.fill(i as f32);
        });
        for s in 0..6 {
            assert!(data[s * 4..(s + 1) * 4].iter()
                    .all(|&x| x == s as f32));
        }
    }

    #[test]
    fn pool_clamps_workers() {
        assert_eq!(WorkerPool::new(0).workers(), 1);
        assert!(WorkerPool::auto().workers() >= 1);
    }
}
