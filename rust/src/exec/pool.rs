//! ct-contract: bit-exact
//!
//! Scoped data-parallel worker pool for the batched attention engine.
//!
//! Std-only (`std::thread::scope`), no queues or long-lived threads: a
//! [`WorkerPool`] is just a worker-count policy, and each `map_indexed`
//! call spawns scoped workers that claim slice indices from an atomic
//! counter.  Results are gathered per worker and scattered back in index
//! order, so the output `Vec` is **independent of thread scheduling** —
//! combined with per-slice PRNG streams (`prng::slice_stream`) this makes
//! parallel kernel output bit-identical to a sequential loop.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A worker budget shared by several concurrent batch executors (one per
/// serving bucket in the gateway).  Each batched call takes a [`lease`]
/// first; the lease's [`WorkerPool`] is sized from the permits still
/// available, so the workers held by all live leases **never sum above
/// the budget**: a lone flush gets every core, a concurrent flush gets
/// what remains (fair-capped at `total / live leases`), and when the
/// budget is exhausted `lease` blocks until a lease drops — queueing the
/// flush instead of oversubscribing the host.
///
/// One lease per flush, released (dropped) before the next `lease` call
/// from the same thread — a thread holding a lease while taking another
/// can block itself when the budget is spent.
///
/// Worker count never changes *results* — the per-slice PRNG stream
/// contract makes kernel output independent of pool size — so dynamic
/// sizing is invisible to callers beyond throughput.
///
/// [`lease`]: SharedWorkerPool::lease
#[derive(Debug)]
pub struct SharedWorkerPool {
    total: usize,
    state: std::sync::Mutex<PoolBudget>,
    freed: std::sync::Condvar,
}

#[derive(Debug)]
struct PoolBudget {
    /// Worker permits not held by any live lease.
    available: usize,
    /// Live leases, including ones blocked waiting for permits.
    active: usize,
}

impl SharedWorkerPool {
    /// Budget of `total` workers (clamped to >= 1).
    pub fn new(total: usize) -> Self {
        let total = total.max(1);
        Self {
            total,
            state: std::sync::Mutex::new(PoolBudget {
                available: total,
                active: 0,
            }),
            freed: std::sync::Condvar::new(),
        }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        Self::new(WorkerPool::auto().workers())
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Live leases right now (including ones waiting for permits).
    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// Claim worker permits for one batched call: `min(available,
    /// max(1, total / live leases))`, blocking while no permit is free.
    /// The permits return to the budget when the lease drops.
    pub fn lease(&self) -> PoolLease<'_> {
        let mut st = self.state.lock().unwrap();
        st.active += 1;
        while st.available == 0 {
            st = self.freed.wait(st).unwrap();
        }
        let fair = (self.total / st.active).max(1);
        let take = fair.min(st.available);
        st.available -= take;
        PoolLease {
            owner: self,
            pool: WorkerPool::new(take),
            permits: take,
        }
    }
}

/// RAII share of a [`SharedWorkerPool`]; derefs to a sized [`WorkerPool`].
#[derive(Debug)]
pub struct PoolLease<'a> {
    owner: &'a SharedWorkerPool,
    pool: WorkerPool,
    permits: usize,
}

impl std::ops::Deref for PoolLease<'_> {
    type Target = WorkerPool;

    fn deref(&self) -> &WorkerPool {
        &self.pool
    }
}

impl Drop for PoolLease<'_> {
    fn drop(&mut self) {
        let mut st = self.owner.state.lock().unwrap();
        st.available += self.permits;
        st.active -= 1;
        self.owner.freed.notify_all();
    }
}

/// Worker-count policy for scoped data-parallel maps.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    /// Pool with an explicit worker count (clamped to >= 1).
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// One worker: runs inline on the caller thread.
    pub fn sequential() -> Self {
        Self { workers: 1 }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        Self::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Compute `f(i)` for `i in 0..n` and return results in index order.
    ///
    /// Work is claimed dynamically (atomic counter), results are written
    /// back by index, so the output is deterministic regardless of how
    /// the scheduler interleaves workers.
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.workers == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.workers.min(n);
        let next = AtomicUsize::new(0);
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, f(i)));
                        }
                        local
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("pool worker panicked") {
                    out[i] = Some(r);
                }
            }
        });
        out.into_iter()
            .map(|r| r.expect("pool missed an index"))
            .collect()
    }

    /// Zip `f(i, chunk_i)` over pre-split disjoint mutable chunks (e.g.
    /// `BatchMatrix::slices_mut`), claiming indices dynamically.
    pub fn for_each_mut<T, F>(&self, chunks: Vec<&mut T>, f: F)
    where
        T: Send + ?Sized,
        F: Fn(usize, &mut T) + Sync,
    {
        if self.workers == 1 || chunks.len() <= 1 {
            for (i, c) in chunks.into_iter().enumerate() {
                f(i, c);
            }
            return;
        }
        let n = chunks.len();
        let workers = self.workers.min(n);
        let next = AtomicUsize::new(0);
        // hand each chunk its own cell so workers can claim arbitrary
        // indices without aliasing
        let cells: Vec<std::sync::Mutex<Option<&mut T>>> = chunks
            .into_iter()
            .map(|c| std::sync::Mutex::new(Some(c)))
            .collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let chunk = cells[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("chunk claimed twice");
                    f(i, chunk);
                });
            }
        });
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_indexed_returns_results_in_index_order() {
        let pool = WorkerPool::new(4);
        let got = pool.map_indexed(100, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn map_indexed_matches_sequential_for_any_worker_count() {
        for w in [1, 2, 3, 8, 64] {
            let got = WorkerPool::new(w).map_indexed(17, |i| 3 * i + 1);
            let want = WorkerPool::sequential().map_indexed(17, |i| 3 * i + 1);
            assert_eq!(got, want, "workers={w}");
        }
    }

    #[test]
    fn map_indexed_runs_each_index_exactly_once() {
        let hits: Vec<AtomicUsize> =
            (0..37).map(|_| AtomicUsize::new(0)).collect();
        WorkerPool::new(5).map_indexed(37, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_indexed_empty_and_single() {
        let pool = WorkerPool::new(8);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn for_each_mut_writes_every_chunk() {
        let mut data = vec![0f32; 6 * 4];
        let chunks: Vec<&mut [f32]> = data.chunks_mut(4).collect();
        WorkerPool::new(3).for_each_mut(chunks, |i, c| {
            c.fill(i as f32);
        });
        for s in 0..6 {
            assert!(data[s * 4..(s + 1) * 4].iter()
                    .all(|&x| x == s as f32));
        }
    }

    #[test]
    fn pool_clamps_workers() {
        assert_eq!(WorkerPool::new(0).workers(), 1);
        assert!(WorkerPool::auto().workers() >= 1);
    }

    #[test]
    fn shared_pool_lone_lease_gets_and_returns_the_full_budget() {
        let shared = SharedWorkerPool::new(8);
        let a = shared.lease();
        assert_eq!(a.workers(), 8);
        assert_eq!(shared.active(), 1);
        drop(a);
        assert_eq!(shared.active(), 0);
        // budget restored once the lease drops
        assert_eq!(shared.lease().workers(), 8);
    }

    #[test]
    fn shared_pool_concurrent_leases_never_exceed_the_budget() {
        use std::sync::Arc;
        let shared = Arc::new(SharedWorkerPool::new(4));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (shared, in_flight, peak) =
                    (shared.clone(), in_flight.clone(), peak.clone());
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        let lease = shared.lease();
                        assert!(lease.workers() >= 1);
                        let now = in_flight
                            .fetch_add(lease.workers(), Ordering::SeqCst)
                            + lease.workers();
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::yield_now();
                        in_flight
                            .fetch_sub(lease.workers(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // the no-oversubscription invariant: held workers never sum
        // above the budget, no matter how leases interleave
        assert!(peak.load(Ordering::SeqCst) <= 4,
                "peak {} > budget", peak.load(Ordering::SeqCst));
        assert_eq!(shared.active(), 0);
    }

    #[test]
    fn shared_pool_lease_runs_maps_like_a_plain_pool() {
        let shared = SharedWorkerPool::new(4);
        let lease = shared.lease();
        let got = lease.map_indexed(10, |i| i * 2);
        assert_eq!(got, (0..10).map(|i| i * 2).collect::<Vec<_>>());
        assert!(SharedWorkerPool::auto().total() >= 1);
        assert_eq!(SharedWorkerPool::new(0).total(), 1);
    }
}
