//! ct-contract: bit-exact
//!
//! Intra-op execution context: a pool handle plus the parallelism
//! threshold every row-partitioned primitive consults.
//!
//! [`ExecCtx`] is the seam the tiled compute core (`tensor::gemm`, the
//! streaming softmax path, LSH hashing, K-Means assignment, the
//! improved-attention per-query pass) parallelizes through.  The rule
//! that keeps parallel output bit-identical to sequential output:
//!
//! > **Partition output rows, never split a reduction.**
//!
//! Workers own disjoint contiguous row ranges of the output; every
//! reduction (a GEMM k-sum, a softmax normalizer, a top-k scan) runs
//! entirely inside the worker that owns its output row, in the same
//! order a sequential loop would use.  Chunk boundaries therefore never
//! change a single arithmetic operation — only which thread executes it
//! — so results are independent of the worker count (including 1).
//! `proptest/attention_props.rs` enforces this for every kernel family.

use crate::exec::WorkerPool;

/// Default minimum output rows before an op splits across the pool.
/// Below this the fork/join overhead of scoped workers outweighs the
/// work (a 64-row GEMM stripe is microseconds).
pub const DEFAULT_PAR_ROWS: usize = 64;

/// Pool handle + parallelism threshold threaded through
/// [`crate::attention::AttentionKernel::solve`] and the compute core.
#[derive(Debug, Clone, Copy)]
pub struct ExecCtx {
    pool: WorkerPool,
    /// Minimum output rows before an op partitions over the pool.
    par_rows: usize,
}

impl ExecCtx {
    /// Context over `pool` with the default row threshold.
    pub fn new(pool: WorkerPool) -> Self {
        Self { pool, par_rows: DEFAULT_PAR_ROWS }
    }

    /// Context with an explicit threshold (`0` = [`DEFAULT_PAR_ROWS`]).
    pub fn with_par_rows(pool: WorkerPool, par_rows: usize) -> Self {
        let par_rows = if par_rows == 0 { DEFAULT_PAR_ROWS } else { par_rows };
        Self { pool, par_rows }
    }

    /// Single-worker context: every op runs inline on the caller.
    pub fn sequential() -> Self {
        Self { pool: WorkerPool::sequential(), par_rows: usize::MAX }
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    pub fn par_rows(&self) -> usize {
        self.par_rows
    }

    /// Should an op with `rows` output rows split across the pool?
    pub fn should_par(&self, rows: usize) -> bool {
        self.pool.workers() > 1 && rows >= self.par_rows
    }

    /// `f(i)` for `i in 0..n`, results in index order — split across
    /// the pool when the row threshold says so, inline otherwise.  The
    /// map-shaped sibling of [`par_rows`]; like it, `f` must make each
    /// index's result independent of every other, which keeps the
    /// output identical for any worker count.
    pub fn map_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.should_par(n) {
            self.pool.map_indexed(n, f)
        } else {
            (0..n).map(f).collect()
        }
    }

    /// Split the worker budget between `slices` outer tasks (the
    /// batched (batch × head) axis) and the per-task inner context.
    ///
    /// Many slices → all workers go outer, inner runs sequential (the
    /// pre-compute-core schedule).  Few slices (a lone long-N request)
    /// → the leftover workers move inside the slice, so single-sequence
    /// latency still uses the whole budget.  The outer width maximizes
    /// busy workers (`outer · ⌊total/outer⌋`), preferring the cheaper
    /// slice axis on ties — a 5-slice batch on 8 workers runs 4×2, not
    /// 5×1 with three idle.  Worker placement never changes output
    /// bits, so the split is invisible beyond speed.
    pub fn split_batch(&self, slices: usize) -> (WorkerPool, ExecCtx) {
        let total = self.pool.workers();
        let mut best = (1usize, 1usize);
        for outer in 1..=total.min(slices.max(1)) {
            let inner = total / outer;
            // >= : later (wider-outer) candidates win ties
            if outer * inner >= best.0 * best.1 {
                best = (outer, inner);
            }
        }
        (
            WorkerPool::new(best.0),
            ExecCtx { pool: WorkerPool::new(best.1),
                      par_rows: self.par_rows },
        )
    }
}

impl Default for ExecCtx {
    fn default() -> Self {
        Self::new(WorkerPool::auto())
    }
}

/// Run `f(row_range, chunk)` over contiguous row blocks of a row-major
/// buffer of `rows` rows × `stride` elements — the one way compute-core
/// primitives go parallel.
///
/// The buffer is split into at most `ctx.workers()` contiguous chunks;
/// each invocation gets the global row range it owns and the mutable
/// storage of exactly those rows.  `f` must compute each row the same
/// way regardless of which chunk contains it (no cross-row state), which
/// makes the result bit-identical to the sequential call `f(0..rows,
/// buf)` for any worker count.  When `ctx` declines parallelism the
/// sequential call is exactly what happens.
pub fn par_rows<T, F>(ctx: &ExecCtx, buf: &mut [T], rows: usize,
                      stride: usize, f: F)
where
    T: Send,
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    debug_assert_eq!(buf.len(), rows * stride, "par_rows shape mismatch");
    if rows == 0 || stride == 0 {
        return;
    }
    if !ctx.should_par(rows) {
        f(0..rows, buf);
        return;
    }
    let rows_per_chunk = rows.div_ceil(ctx.workers());
    let chunks: Vec<&mut [T]> = buf.chunks_mut(rows_per_chunk * stride).collect();
    ctx.pool().for_each_mut(chunks, |ci, chunk| {
        let r0 = ci * rows_per_chunk;
        let r1 = r0 + chunk.len() / stride;
        f(r0..r1, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_and_defaults() {
        let ctx = ExecCtx::new(WorkerPool::new(4));
        assert_eq!(ctx.workers(), 4);
        assert_eq!(ctx.par_rows(), DEFAULT_PAR_ROWS);
        assert!(ctx.should_par(DEFAULT_PAR_ROWS));
        assert!(!ctx.should_par(DEFAULT_PAR_ROWS - 1));
        assert!(!ExecCtx::sequential().should_par(usize::MAX - 1));
        assert_eq!(ExecCtx::with_par_rows(WorkerPool::new(2), 0).par_rows(),
                   DEFAULT_PAR_ROWS);
        assert_eq!(ExecCtx::with_par_rows(WorkerPool::new(2), 7).par_rows(),
                   7);
        assert!(ExecCtx::default().workers() >= 1);
    }

    #[test]
    fn split_batch_balances_outer_and_inner() {
        let ctx = ExecCtx::new(WorkerPool::new(8));
        // many slices: all workers outer, inner sequential
        let (outer, inner) = ctx.split_batch(16);
        assert_eq!(outer.workers(), 8);
        assert_eq!(inner.workers(), 1);
        // one slice: the whole budget moves inside
        let (outer, inner) = ctx.split_batch(1);
        assert_eq!(outer.workers(), 1);
        assert_eq!(inner.workers(), 8);
        // threshold survives the split
        assert_eq!(inner.par_rows(), ctx.par_rows());
        // awkward slice counts still keep every worker busy: 5 slices
        // on 8 workers runs 4 outer × 2 inner, not 5 × 1 with 3 idle
        let (outer, inner) = ctx.split_batch(5);
        assert_eq!((outer.workers(), inner.workers()), (4, 2));
        // degenerate: zero slices must not panic or divide by zero
        let (outer, inner) = ctx.split_batch(0);
        assert!(outer.workers() >= 1 && inner.workers() >= 1);
    }

    #[test]
    fn par_rows_covers_every_row_once_for_any_worker_count() {
        for workers in [1, 2, 3, 5, 8] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            let (rows, stride) = (23, 3);
            let mut buf = vec![0u32; rows * stride];
            par_rows(&ctx, &mut buf, rows, stride, |range, chunk| {
                for (off, r) in range.enumerate() {
                    for c in 0..stride {
                        chunk[off * stride + c] = (r * stride + c) as u32;
                    }
                }
            });
            let want: Vec<u32> =
                (0..(rows * stride) as u32).collect();
            assert_eq!(buf, want, "workers={workers}");
        }
    }

    #[test]
    fn map_indexed_matches_inline_map_for_any_worker_count() {
        for workers in [1, 2, 4] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            assert_eq!(ctx.map_indexed(13, |i| 3 * i),
                       (0..13).map(|i| 3 * i).collect::<Vec<_>>(),
                       "workers={workers}");
        }
        // below the threshold it stays inline and still matches
        let ctx = ExecCtx::with_par_rows(WorkerPool::new(4), 100);
        assert_eq!(ctx.map_indexed(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
        assert_eq!(ctx.map_indexed(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_rows_sequential_below_threshold_and_on_empty() {
        let ctx = ExecCtx::with_par_rows(WorkerPool::new(4), 100);
        let mut buf = vec![0u8; 10];
        par_rows(&ctx, &mut buf, 10, 1, |range, chunk| {
            // below threshold: one call owning everything
            assert_eq!(range, 0..10);
            chunk.fill(1);
        });
        assert!(buf.iter().all(|&b| b == 1));
        let mut empty: Vec<u8> = Vec::new();
        par_rows(&ctx, &mut empty, 0, 4, |_, _| panic!("no rows"));
    }
}
