//! ct-contract: bit-exact
//! ct-lint: allow(det-entropy, reason = "Instant::now implements recv_timeout deadlines; timing never reaches kernel outputs")
//!
//! Concurrency substrate (tokio is unavailable offline — DESIGN.md §5).
//!
//! A bounded MPMC channel (mutex + condvars, honest backpressure) and a
//! small worker pool.  The coordinator's event loop is built on these:
//! request queues block producers when full, which is the backpressure
//! signal the serving benches measure.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub mod ctx;
pub mod pool;

pub use ctx::{par_rows, ExecCtx, DEFAULT_PAR_ROWS};
pub use pool::{PoolLease, SharedWorkerPool, WorkerPool};

/// Lock a mutex, recovering from poison instead of panicking.
///
/// The serving surface promised graceful degradation (`ct lint`
/// enforces `panic-unwrap` there): a worker that panicked while
/// holding a metrics or session lock must not take the dispatcher
/// down with it.  The protected state in those paths is always valid
/// at rest (counters, histograms, session tables with per-entry
/// invariants), so continuing with the inner value is strictly better
/// than cascading the panic.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Bounded multi-producer multi-consumer channel.
pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    q: Mutex<ChannelState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct ChannelState<T> {
    buf: VecDeque<T>,
    cap: usize,
    closed: bool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            inner: Arc::new(ChannelInner {
                q: Mutex::new(ChannelState {
                    buf: VecDeque::new(),
                    cap,
                    closed: false,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
            }),
        }
    }

    /// Blocking send; returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.buf.len() < st.cap {
                st.buf.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.q.lock().unwrap();
        if st.closed || st.buf.len() >= st.cap {
            return Err(item);
        }
        st.buf.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with timeout; `Ok(None)` = closed, `Err(())` = timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration)
                        -> Result<Option<T>, ()> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.inner.q.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.inner.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (g, res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = g;
            if res.timed_out() && st.buf.is_empty() && !st.closed {
                return Err(());
            }
        }
    }

    /// Drain up to `max` items without blocking (batcher fast-path).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut st = self.inner.q.lock().unwrap();
        let take = st.buf.len().min(max);
        let out: Vec<T> = st.buf.drain(..take).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn close(&self) {
        let mut st = self.inner.q.lock().unwrap();
        st.closed = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Fixed-size worker pool executing boxed jobs.
pub struct ThreadPool {
    tx: Channel<Box<dyn FnOnce() + Send>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize, queue_cap: usize) -> Self {
        let tx: Channel<Box<dyn FnOnce() + Send>> =
            Channel::bounded(queue_cap.max(1));
        let handles = (0..workers.max(1))
            .map(|i| {
                let rx = tx.clone();
                std::thread::Builder::new()
                    .name(format!("ct-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, handles }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool closed"));
    }

    /// Close the queue and join all workers.
    pub fn shutdown(self) {
        self.tx.close();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across `workers` scoped threads (simple
/// data-parallel helper for the benches).
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, workers: usize, f: F) {
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn channel_fifo() {
        let ch = Channel::bounded(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn channel_backpressure_blocks_until_recv() {
        let ch = Channel::bounded(1);
        ch.send(1).unwrap();
        assert!(ch.try_send(2).is_err());
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || ch2.send(2).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(ch.recv(), Some(1));
        h.join().unwrap();
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn close_unblocks_receivers() {
        let ch: Channel<i32> = Channel::bounded(1);
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || ch2.recv());
        std::thread::sleep(Duration::from_millis(10));
        ch.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Channel<i32> = Channel::bounded(1);
        assert!(ch.recv_timeout(Duration::from_millis(10)).is_err());
        ch.send(5).unwrap();
        assert_eq!(ch.recv_timeout(Duration::from_millis(10)),
                   Ok(Some(5)));
    }

    #[test]
    fn drain_up_to_takes_at_most_max() {
        let ch = Channel::bounded(10);
        for i in 0..6 {
            ch.send(i).unwrap();
        }
        let got = ch.drain_up_to(4);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(ch.len(), 2);
    }

    #[test]
    fn lock_unpoisoned_recovers_inner_value() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn pool_executes_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> =
            (0..50).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(50, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
