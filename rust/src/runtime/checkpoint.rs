//! Flat-vector checkpoints: params + Adam state + step, as one little-
//! endian binary file with a JSON sidecar header.
//!
//! The L2 model keeps all parameters in a single f32 vector (see
//! `python/compile/model.py::param_spec`), so a checkpoint is just three
//! vectors and a counter — no framework serialization needed.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonio::{obj, parse, Value};

const MAGIC: &[u8; 8] = b"CTCKPT01";

/// Training state for one model.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model_name: String,
    pub step: i32,
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    /// free-form metrics recorded at save time (loss curve etc.)
    pub meta: Value,
}

impl Checkpoint {
    pub fn fresh(model_name: &str, params: Vec<f32>, adam_m: Vec<f32>,
                 adam_v: Vec<f32>) -> Self {
        Self {
            model_name: model_name.to_string(),
            step: 0,
            params,
            adam_m,
            adam_v,
            meta: Value::Null,
        }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let header = obj(vec![
            ("model", self.model_name.as_str().into()),
            ("step", (self.step as i64).into()),
            ("n", self.params.len().into()),
            ("meta", self.meta.clone()),
        ])
        .to_string();
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for vec in [&self.params, &self.adam_m, &self.adam_v] {
            for v in vec {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a checkpoint file");
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let n = header.get("n").as_usize().unwrap_or(0);
        let mut raw = vec![0u8; 3 * n * 4];
        f.read_exact(&mut raw)?;
        let read_vec = |off: usize| -> Vec<f32> {
            raw[off * 4..(off + n) * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect()
        };
        Ok(Self {
            model_name: header.get("model").as_str().unwrap_or("").into(),
            step: header.get("step").as_i64().unwrap_or(0) as i32,
            params: read_vec(0),
            adam_m: read_vec(n),
            adam_v: read_vec(2 * n),
            meta: header.get("meta").clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ct-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let mut c = Checkpoint::fresh("wsj-l6-full",
                                      vec![1.0, -2.5, 3.25],
                                      vec![0.0; 3], vec![0.5; 3]);
        c.step = 42;
        c.meta = obj(vec![("loss", 1.25.into())]);
        c.save(&path).unwrap();
        let d = Checkpoint::load(&path).unwrap();
        assert_eq!(c, d);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ct-ckpt-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }
}
