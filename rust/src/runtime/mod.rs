//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the Rust hot path.
//!
//! `manifest.json` (written by `python -m compile.aot`) declares every
//! program's inputs/outputs/config; [`Runtime`] compiles executables
//! lazily and caches them, so benches and the coordinator share compiled
//! modules.  Interchange is HLO *text* because the pinned xla_extension
//! 0.5.1 rejects jax ≥ 0.5 serialized protos (64-bit instruction ids).
//!
//! ct-lint: allow(det-entropy, reason = "Instant::now measures compile/execute latency for metrics; program outputs are pure functions of their inputs")

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{self, Value};

pub mod checkpoint;

/// Tensor dtype as declared in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One named input/output tensor of a program.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one AOT program.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
    pub config: Value,
    pub param_count: usize,
}

impl Program {
    /// Model config accessors (see `ModelConfig.to_json_dict`).
    pub fn seq_len(&self) -> usize {
        self.config.get("seq_len").as_usize().unwrap_or(0)
    }
    pub fn batch_size(&self) -> usize {
        self.config.get("batch_size").as_usize().unwrap_or(0)
    }
    pub fn model_name(&self) -> &str {
        self.config.get("name").as_str().unwrap_or("")
    }
    pub fn variant(&self) -> String {
        let a = self.config.get("attention");
        let kind = a.get("kind").as_str().unwrap_or("full");
        match kind {
            "clustered" | "i-clustered" => format!(
                "{kind}-{}", a.get("clusters").as_usize().unwrap_or(0)),
            "lsh" => format!("lsh-{}", a.get("rounds").as_usize().unwrap_or(1)),
            other => other.to_string(),
        }
    }
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

/// A typed host tensor headed into / out of an executable.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => Err(anyhow!("expected i32 tensor")),
        }
    }
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v])
    }
    pub fn scalar_f32_value(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }
}

fn to_literal(spec: &TensorSpec, t: &HostTensor) -> Result<xla::Literal> {
    if t.len() != spec.elements() {
        bail!("input {:?}: got {} elements, want {} (shape {:?})",
              spec.name, t.len(), spec.elements(), spec.shape);
    }
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match (spec.dtype, t) {
        (Dtype::F32, HostTensor::F32(v)) => xla::Literal::vec1(v),
        (Dtype::I32, HostTensor::I32(v)) => xla::Literal::vec1(v),
        _ => bail!("dtype mismatch for input {:?}", spec.name),
    };
    if dims.is_empty() {
        // scalar: reshape to rank-0
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims)?)
    }
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    use xla::ElementType as ET;
    match lit.ty()? {
        ET::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?)),
        ET::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?)),
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// A compiled program.
pub struct Executable {
    pub program: Program,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with named-order host tensors; returns output tensors in
    /// manifest order (the lowered module returns one tuple).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits = self.prepare(inputs)?;
        self.run_literals(&lits)
    }

    /// Convert host tensors to XLA literals (shape/dtype-checked).
    /// Serving hot paths prepare loop-invariant inputs (e.g. the model
    /// parameters) ONCE and reuse them across `run_literals` calls —
    /// see EXPERIMENTS.md §Perf for the measured effect.
    pub fn prepare(&self, inputs: &[HostTensor])
                   -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.program.inputs.len() {
            bail!("{}: got {} inputs, want {}", self.program.name,
                  inputs.len(), self.program.inputs.len());
        }
        self.program
            .inputs
            .iter()
            .zip(inputs)
            .map(|(s, t)| to_literal(s, t))
            .collect()
    }

    /// Convert ONE input at its manifest position (for mixed cached /
    /// per-call input assembly).
    pub fn prepare_one(&self, index: usize, t: &HostTensor)
                       -> Result<xla::Literal> {
        let spec = self
            .program
            .inputs
            .get(index)
            .ok_or_else(|| anyhow!("input index {index} out of range"))?;
        to_literal(spec, t)
    }

    /// Execute with pre-converted literals.
    pub fn run_literals(&self, lits: &[xla::Literal])
                        -> Result<Vec<HostTensor>> {
        let result = self.exe.execute::<xla::Literal>(lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(from_literal).collect()
    }

    /// Execute with borrowed literals — lets hot paths mix long-lived
    /// cached inputs (params) with per-call tensors without cloning.
    pub fn run_literals_borrowed(&self, lits: &[&xla::Literal])
                                 -> Result<Vec<HostTensor>> {
        let result = self.exe.execute::<&xla::Literal>(lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(from_literal).collect()
    }
}

/// The runtime: PJRT CPU client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    programs: HashMap<String, Program>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Load `artifacts/manifest.json` and start the PJRT CPU client.
    pub fn open<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run \
                                      `make artifacts` first"))?;
        let root = jsonio::parse(&text)
            .map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut programs = HashMap::new();
        for entry in root.get("programs").as_arr().unwrap_or(&[]) {
            let p = parse_program(entry)?;
            programs.insert(p.name.clone(), p);
        }
        let client = xla::PjRtClient::cpu()?;
        log::info!("runtime: {} programs, platform={}", programs.len(),
                   client.platform_name());
        Ok(Self { client, dir, programs, cache: Mutex::new(HashMap::new()) })
    }

    pub fn program(&self, name: &str) -> Result<&Program> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("program {name:?} not in manifest"))
    }

    pub fn program_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.programs.keys().cloned().collect();
        v.sort();
        v
    }

    /// Programs whose name matches a substring filter.
    pub fn find(&self, substr: &str) -> Vec<&Program> {
        let mut v: Vec<&Program> = self
            .programs
            .values()
            .filter(|p| p.name.contains(substr))
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let program = self.program(name)?.clone();
        let path = self.dir.join(&program.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let arc = Arc::new(Executable { program, exe });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }
}

fn parse_program(v: &Value) -> Result<Program> {
    let tensor = |t: &Value| -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: t.get("name").as_str().unwrap_or("").to_string(),
            shape: t
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: Dtype::parse(t.get("dtype").as_str().unwrap_or(""))?,
        })
    };
    Ok(Program {
        name: v.get("name").as_str().unwrap_or("").to_string(),
        kind: v.get("kind").as_str().unwrap_or("").to_string(),
        file: v.get("file").as_str().unwrap_or("").to_string(),
        inputs: v
            .get("inputs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(tensor)
            .collect::<Result<_>>()?,
        outputs: v
            .get("outputs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| s.as_str().unwrap_or("").to_string())
            .collect(),
        config: v.get("config").clone(),
        param_count: v.get("param_count").as_usize().unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("bfloat16").is_err());
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { name: "x".into(), shape: vec![4, 8],
                             dtype: Dtype::F32 };
        assert_eq!(t.elements(), 32);
        let s = TensorSpec { name: "s".into(), shape: vec![],
                             dtype: Dtype::I32 };
        assert_eq!(s.elements(), 1);
    }

    #[test]
    fn parse_program_from_json() {
        let v = jsonio::parse(
            r#"{"name":"m.train","kind":"train","file":"m.hlo.txt",
                "inputs":[{"name":"params","shape":[10],"dtype":"float32"},
                           {"name":"seed","shape":[],"dtype":"int32"}],
                "outputs":["params","loss"],
                "config":{"seq_len":64,"batch_size":4,
                           "attention":{"kind":"clustered","clusters":25}},
                "param_count":10}"#,
        )
        .unwrap();
        let p = parse_program(&v).unwrap();
        assert_eq!(p.name, "m.train");
        assert_eq!(p.inputs.len(), 2);
        assert_eq!(p.inputs[1].shape.len(), 0);
        assert_eq!(p.seq_len(), 64);
        assert_eq!(p.variant(), "clustered-25");
        assert_eq!(p.input_index("seed"), Some(1));
    }
}
