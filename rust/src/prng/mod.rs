//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256**), the workhorse
//! generator.  Streams are *stable across runs and platforms*: every
//! synthetic dataset in `data/` derives from an explicit seed so that
//! experiments are exactly reproducible.

/// Independent per-slice stream for batched (batch × head) kernels.
///
/// **Determinism contract:** slice `s` of a batched operation draws from
/// `slice_stream(seed, s)` and nothing else, so the result of a batched
/// run is a pure function of `(seed, slice index)` — independent of how
/// many pool workers ran it or in which order slices were claimed.
/// Sequential and parallel schedules are therefore bit-identical.
pub fn slice_stream(seed: u64, slice: u64) -> Xoshiro256 {
    Xoshiro256::new(seed).fold_in(slice)
}

/// Derive a decode session's base seed from the gateway seed and the
/// session id.
///
/// Incremental-decode sequences draw their per-head streams from
/// `slice_stream(session_seed(seed, session), head)` instead of the
/// batch-slot stream, so a session's output is a pure function of
/// `(history, seed, session id, head)` — **independent of which batch
/// slot the step landed in or what traffic it was co-batched with**.
/// That slot-independence is what lets an incremental step (computed
/// against the KV cache) be bit-identical to a full recompute of the
/// same history submitted later, in a different batch composition.
pub fn session_seed(seed: u64, session: u64) -> u64 {
    let mut sm = SplitMix64::new(
        seed.rotate_left(32) ^ session.wrapping_mul(0xD1B54A32D192ED03));
    sm.next_u64()
}

/// SplitMix64 — tiny, used for seeding and for hash-style key folding.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (`jax.random.fold_in` analogue).
    pub fn fold_in(&self, data: u64) -> Self {
        let mut sm = SplitMix64::new(self.s[0] ^ data.wrapping_mul(0x9E3779B97F4A7C15));
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices out of `n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_sequence_is_stable() {
        let mut r = SplitMix64::new(1234);
        let a: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
        let mut r2 = SplitMix64::new(1234);
        let b: Vec<u64> = (0..3).map(|_| r2.next_u64()).collect();
        assert_eq!(a, b);
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Xoshiro256::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fold_in_gives_independent_streams() {
        let base = Xoshiro256::new(11);
        let mut a = base.fold_in(1);
        let mut b = base.fold_in(2);
        assert_ne!(a.next_u64(), b.next_u64());
        let mut a2 = base.fold_in(1);
        a2.next_u64();
        let mut a3 = base.fold_in(1);
        assert_eq!(a3.next_u64(), {
            let mut t = base.fold_in(1);
            t.next_u64()
        });
        let _ = a2;
    }

    #[test]
    fn session_seed_is_stable_and_separates_sessions() {
        assert_eq!(session_seed(7, 42), session_seed(7, 42));
        assert_ne!(session_seed(7, 42), session_seed(7, 43));
        assert_ne!(session_seed(7, 42), session_seed(8, 42));
        // the derived streams are independent of the base slice streams
        let mut a = slice_stream(session_seed(7, 42), 0);
        let mut b = slice_stream(7, 42);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::new(13);
        let idx = r.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
