//! `ct` — the clustered-transformers launcher.
//!
//! ct-lint: allow(det-entropy, reason = "the CLI shell times benches and stamps reports; kernel math never sees the clock")
//!
//! Subcommands:
//!   list        show manifest programs
//!   train       train one model via compiled train-step HLO
//!   eval        evaluate a checkpoint with any attention variant
//!   serve       run the TCP inference server (compiled HLO buckets)
//!   gateway     multi-bucket native attention gateway: replay a
//!               synthetic mixed-length trace (default) or serve TCP
//!   shard-worker  serve raw attention sub-batches (binary-framed f32)
//!               for a multi-host gateway's sharded fan-out backend
//!   oracle      golden-trace regression harness: record / replay /
//!               bless fixtures, run the bench perf gate
//!               (see docs/TESTING.md)
//!   lint        contract-aware static analysis over the crate's own
//!               sources: determinism, panic-safety, wire-stability
//!               and doc-drift rules with reasoned suppressions,
//!               emitting a byte-stable lint-report.json
//!               (see docs/TESTING.md)
//!   validate    run every *.forward program once (artifact smoke test)
//!   bench-attn  quick native attention timing (see benches for full runs)

use std::sync::Arc;

use anyhow::{anyhow, Result};
use clustered_transformers::cli::Command;
use clustered_transformers::config::{find_repo_root, init_logging, RunConfig};
use clustered_transformers::coordinator::{
    self, trainer, DataFeed, InferenceEngine, ServeOptions, TrainOptions,
};
use clustered_transformers::data::Split;
use clustered_transformers::runtime::{checkpoint::Checkpoint, HostTensor,
                                      Runtime};
use clustered_transformers::{attention, benchlib, prng, tensor};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match sub {
        "list" => cmd_list(rest),
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "gateway" => cmd_gateway(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "oracle" => cmd_oracle(rest),
        "lint" => cmd_lint(rest),
        "validate" => cmd_validate(rest),
        "bench-attn" => cmd_bench_attn(rest),
        _ => {
            println!(
                "ct — Fast Transformers with Clustered Attention (repro)\n\
                 subcommands: list | train | eval | serve | gateway | \
                 shard-worker | oracle | lint | validate | bench-attn\n\
                 run `ct <subcommand> --help` conceptually via source; \
                 common options: --artifacts DIR --steps N --model NAME"
            );
            Ok(())
        }
    }
}

/// Parse `--cache-quant` (shared by `gateway` and `shard-worker`),
/// rejecting unknown spellings loudly.
fn parse_cache_quant(args: &clustered_transformers::cli::Args)
                     -> Result<attention::CacheQuant> {
    let s = args.get_or("cache-quant", "off");
    attention::CacheQuant::parse(&s).ok_or_else(|| anyhow!(
        "--cache-quant expects off | i8-head | i8-panel, got {s:?}"))
}

fn open_runtime(args: &clustered_transformers::cli::Args) -> Result<Runtime> {
    let root = find_repo_root();
    let dir = args.get_or("artifacts",
                          root.join("artifacts").to_str().unwrap());
    Runtime::open(dir)
}

fn cmd_list(rest: &[String]) -> Result<()> {
    let cmd = Command::new("list", "show manifest programs")
        .opt("artifacts", None, "artifacts directory")
        .opt("filter", Some(""), "substring filter");
    let args = cmd.parse(rest)?;
    init_logging(false);
    let rt = open_runtime(&args)?;
    let filter = args.get_or("filter", "");
    for name in rt.program_names() {
        if name.contains(&filter) {
            let p = rt.program(&name)?;
            println!("{:60} {:8} N={:<5} B={:<3} params={}", name, p.kind,
                     p.seq_len(), p.batch_size(), p.param_count);
        }
    }
    Ok(())
}

fn cmd_train(rest: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train a model from the manifest")
        .opt("artifacts", None, "artifacts directory")
        .opt("model", None, "model name, e.g. copy-n64-full")
        .opt("steps", Some("400"), "optimizer steps")
        .opt("eval-every", Some("50"), "validation cadence")
        .opt("patience", Some("0"), "early-stop patience (0 = off)")
        .opt("seed", Some("0"), "seed")
        .opt("out", None, "checkpoint output path");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let model = args
        .get("model")
        .ok_or_else(|| anyhow!("--model is required\n{}", cmd.usage()))?
        .to_string();
    let rt = open_runtime(&args)?;
    let opts = TrainOptions {
        steps: args.get_u64("steps", 400)?,
        eval_every: args.get_u64("eval-every", 50)?,
        patience: args.get_u64("patience", 0)?,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    };
    let (ckpt, result) = trainer::train_model(&rt, &model, &opts)?;
    println!(
        "trained {model}: {} steps, {:.1}s total ({:.3}s/step), final loss \
         {:.4}, best val {:.4}",
        result.steps_run, result.wall_seconds, result.seconds_per_step,
        result.final_loss, result.best_val_loss
    );
    let cfg = RunConfig::default();
    cfg.ensure_dirs()?;
    let out = args
        .get("out")
        .map(|s| s.into())
        .unwrap_or_else(|| cfg.checkpoint_path(&model));
    ckpt.save(&out)?;
    println!("checkpoint: {}", out.display());
    Ok(())
}

fn cmd_eval(rest: &[String]) -> Result<()> {
    let cmd = Command::new("eval", "evaluate a checkpoint with a variant")
        .opt("artifacts", None, "artifacts directory")
        .opt("checkpoint", None, "checkpoint path")
        .opt("forward", None, "forward program name (the eval variant)")
        .opt("batches", Some("8"), "validation batches")
        .opt("seed", Some("0"), "seed");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let rt = open_runtime(&args)?;
    let ckpt_path = args
        .get("checkpoint")
        .ok_or_else(|| anyhow!("--checkpoint required"))?;
    let fwd = args
        .get("forward")
        .ok_or_else(|| anyhow!("--forward required"))?;
    let ckpt = Checkpoint::load(ckpt_path)?;
    let prog = rt.program(fwd)?.clone();
    let feed = DataFeed::for_program(&prog, args.get_u64("seed", 0)?)?;
    let batches = args.get_u64("batches", 8)?;
    let evals = trainer::forward_eval(&rt, fwd, &ckpt.params, &feed,
                                      Split::Test, batches, 0)?;
    let report = clustered_transformers::coordinator::trainer::score(
        &prog, &feed, &evals)?;
    println!("{fwd}: {report}");
    Ok(())
}

fn cmd_validate(rest: &[String]) -> Result<()> {
    let cmd = Command::new("validate", "run every forward program once")
        .opt("artifacts", None, "artifacts directory")
        .opt("filter", Some(""), "substring filter");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let rt = open_runtime(&args)?;
    let filter = args.get_or("filter", "");
    let mut ran = 0;
    for name in rt.program_names() {
        if !name.ends_with(".forward") || !name.contains(&filter) {
            continue;
        }
        let exe = rt.load(&name)?;
        let p = &exe.program;
        let inputs: Vec<HostTensor> = p
            .inputs
            .iter()
            .map(|spec| match spec.dtype {
                clustered_transformers::runtime::Dtype::F32 => {
                    HostTensor::F32(vec![0.01; spec.elements()])
                }
                clustered_transformers::runtime::Dtype::I32 => {
                    HostTensor::I32(vec![1; spec.elements()])
                }
            })
            .collect();
        let out = exe.run(&inputs)?;
        let finite = out.iter().all(|t| match t {
            HostTensor::F32(v) => v.iter().all(|x| x.is_finite()),
            HostTensor::I32(_) => true,
        });
        println!("ok {name} -> {} outputs (finite: {finite})", out.len());
        anyhow::ensure!(finite, "{name} produced non-finite outputs");
        ran += 1;
    }
    println!("validated {ran} forward programs");
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "TCP inference server")
        .opt("artifacts", None, "artifacts directory")
        .opt("checkpoint", None, "checkpoint path")
        .opt("forward", None, "comma-separated forward programs (buckets)")
        .opt("addr", Some("127.0.0.1:7878"), "bind address")
        .opt("max-wait-ms", Some("5"), "batcher deadline");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let rt = open_runtime(&args)?;
    let fwd: Vec<String> = args
        .get("forward")
        .ok_or_else(|| anyhow!("--forward required"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let params = match args.get("checkpoint") {
        Some(p) => Checkpoint::load(p)?.params,
        None => {
            // init from the matching init program
            let model = fwd[0].trim_end_matches(".forward");
            let init = rt.load(&format!("{model}.init"))?;
            init.run(&[HostTensor::scalar_i32(0)])?
                .remove(0)
                .into_f32()?
        }
    };
    let mut opts = ServeOptions::default();
    opts.policy.max_wait =
        std::time::Duration::from_millis(args.get_u64("max-wait-ms", 5)?);
    let engine = Arc::new(InferenceEngine::start(&rt, &fwd, params, opts)?);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let addr = args.get_or("addr", "127.0.0.1:7878");
    println!("serving on {addr} (ctrl-c to stop)");
    clustered_transformers::server::serve(engine, &addr, stop, |a| {
        println!("bound {a}");
    })
}

fn cmd_gateway(rest: &[String]) -> Result<()> {
    let cmd = Command::new("gateway",
                          "multi-bucket native attention serving gateway")
        .opt("buckets", Some("64,128,256"), "pad-to lengths, csv")
        .opt("batch", Some("8"), "max co-batched requests per bucket")
        .opt("kernel", Some("i-clustered-8"),
             "attention kernel registry name, every bucket")
        .opt("heads", Some("4"), "heads per request")
        .opt("dk", Some("32"), "query/key head dim")
        .opt("dv", Some("32"), "value head dim")
        .opt("requests", Some("64"), "synthetic trace length (trace mode)")
        .opt("clients", Some("4"), "concurrent submitters (trace mode)")
        .opt("sessions", Some("0"),
             "decode sessions in the trace (0 = one-shot trace only)")
        .opt("prefill", Some("0"),
             "decode session prefill rows (0 = half the smallest bucket)")
        .opt("decode-steps", Some("8"), "decode steps per session")
        .opt("step-len", Some("1"), "new rows per decode step")
        .opt("cache-rows", Some("0"),
             "KV-cache capacity in cached sequence rows (0 = unbounded)")
        .opt("cache-growth", Some("1.0"),
             "clustered re-cluster threshold (1.0 = exact every step)")
        .opt("cache-quant", Some("off"),
             "KV-panel storage: off | i8-head | i8-panel (i8 packs \
              ~4x more live sessions per cached byte; decode is \
              tolerance-gated instead of bit-identical)")
        .opt("max-wait-ms", Some("2"), "batcher deadline")
        .opt("queue", Some("64"), "per-bucket ingress queue capacity")
        .opt("workers", Some("0"), "shared worker budget (0 = auto)")
        .opt("seed", Some("0"), "trace + clustering seed")
        .opt("par-rows", Some("0"),
             "min output rows before intra-slice ops go parallel \
              (0 = default threshold)")
        .flag("no-mask",
              "disable valid-length masking: padded rows participate in \
               the compute (pre-masking static-shape semantics)")
        .flag("causal",
              "autoregressive attention: row i attends keys j <= i; \
               needs a causal-capable kernel (--kernel linear) and \
               decode sessions take the O(1) recurrent-state cache path")
        .opt("session-ttl-ms", Some("0"),
             "evict decode sessions idle this long (0 = never); \
              releases their cache capacity and table entries")
        .opt("shards", None,
             "comma-separated ct shard-worker addresses: serve \
              multi-host through the sharded fan-out backend \
              (sessions route to their owning shard by consistent hash)")
        .opt("addr", None, "bind address: serve TCP instead of a trace");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let kernel = args.get_or("kernel", "i-clustered-8");
    if attention::Variant::parse(&kernel).is_none() {
        return Err(anyhow!(
            "unknown kernel {kernel:?}; registered families: {}",
            attention::kernel_families().join(", ")));
    }
    let batch = args.get_usize("batch", 8)?;
    let buckets: Vec<coordinator::Bucket> = args
        .get_or("buckets", "64,128,256")
        .split(',')
        .map(|s| -> Result<coordinator::Bucket> {
            let n: usize = s.trim().parse().map_err(
                |_| anyhow!("--buckets expects integers, got {s:?}"))?;
            Ok(coordinator::Bucket::native(kernel.clone(), n, batch))
        })
        .collect::<Result<_>>()?;
    let shape = coordinator::GatewayShape {
        heads: args.get_usize("heads", 4)?,
        dk: args.get_usize("dk", 32)?,
        dv: args.get_usize("dv", 32)?,
    };
    let seed = args.get_u64("seed", 0)?;
    let mask = !args.flag("no-mask");
    let cache_rows = args.get_usize("cache-rows", 0)?;
    let cache_quant = parse_cache_quant(&args)?;
    let ttl_ms = args.get_u64("session-ttl-ms", 0)?;
    let shards: Vec<String> = args
        .get("shards")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).collect())
        .unwrap_or_default();
    if !shards.is_empty() {
        println!("multi-host: fanning out across {} shard workers",
                 shards.len());
    }
    let opts = coordinator::GatewayOptions {
        max_wait: std::time::Duration::from_millis(
            args.get_u64("max-wait-ms", 2)?),
        queue_capacity: args.get_usize("queue", 64)?,
        workers: args.get_usize("workers", 0)?, // 0 = auto
        seed,
        route_up: true,
        // intra-slice parallelism threshold (0 = default)
        par_rows: args.get_usize("par-rows", 0)?,
        mask,
        cache_capacity_rows: if cache_rows == 0 { usize::MAX }
                             else { cache_rows },
        cache_growth: args.get_f64("cache-growth", 1.0)?,
        cache_quant,
        session_ttl: if ttl_ms == 0 { None } else {
            Some(std::time::Duration::from_millis(ttl_ms))
        },
        causal: args.flag("causal"),
        shards,
        shard_opts: attention::ShardOptions::default(),
    };
    let gw = coordinator::ServingGateway::start(shape, buckets, opts)?;

    if let Some(addr) = args.get("addr") {
        let gw = Arc::new(gw);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        if ttl_ms > 0 {
            // periodic sweep: the opportunistic per-step sweep can't
            // collect abandoned sessions when decode traffic stops
            let gw2 = gw.clone();
            let stop2 = stop.clone();
            std::thread::spawn(move || {
                let period =
                    std::time::Duration::from_millis((ttl_ms / 2).max(1));
                while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(period);
                    let n = gw2.sweep_expired();
                    if n > 0 {
                        log::info!("session TTL sweep evicted {n}");
                    }
                }
            });
        }
        println!("gateway serving on {addr} (ctrl-c to stop)");
        return clustered_transformers::server::serve_gateway(
            gw, addr, stop, |a| println!("bound {a}"));
    }

    // trace mode: replay a mixed-length (ragged) synthetic trace —
    // optionally mixed with multi-step decode sessions — and report
    // buckets
    let count = args.get_usize("requests", 64)?;
    let clients = args.get_usize("clients", 4)?;
    let sessions = args.get_usize("sessions", 0)?;
    let max_n = gw.router().max_len();
    let min_len = (max_n / 16).max(1);
    let mut trace =
        coordinator::synthetic_trace(shape, min_len, max_n, count, seed);
    if sessions > 0 {
        let min_bucket = gw.router().buckets()[0].seq_len;
        let prefill = match args.get_usize("prefill", 0)? {
            0 => (min_bucket / 2).max(1),
            p => p,
        };
        let steps = args.get_usize("decode-steps", 8)?;
        let step_len = args.get_usize("step-len", 1)?;
        if prefill + steps * step_len > max_n {
            return Err(anyhow!(
                "decode sessions grow to {} rows, over the largest \
                 bucket ({max_n})", prefill + steps * step_len));
        }
        trace.extend(coordinator::synthetic_decode_trace(
            // ct-lint: allow(det-seed-arith, reason = "bench-trace decorrelation constant; bench baselines were recorded under this derivation")
            shape, prefill, steps, step_len, sessions, seed ^ 0xDEC0));
    }
    let total_items = trace.len();
    let t0 = std::time::Instant::now();
    let responses = coordinator::replay_blocking(&gw, trace, clients);
    let wall = t0.elapsed().as_secs_f64();
    let mut table = benchlib::Table::new(
        &format!(
            "gateway: {total_items} requests ({count} one-shot, \
             {sessions} decode sessions), lens {min_len}..{max_n}, \
             {clients} clients, {:.2}s wall, masking {}", wall,
            if mask { "on (responses ≡ unpadded compute)" }
            else { "off (static-shape semantics)" }),
        &coordinator::BUCKET_REPORT_HEADERS,
    );
    for row in coordinator::bucket_report(&gw, wall) {
        table.row(row);
    }
    table.emit();
    let c = gw.cache().counters();
    use std::sync::atomic::Ordering;
    println!("completed {} requests; rejected {}; cache: {} hits / {} \
              misses ({:.1}% hit rate), {} prefix rows reused, {} rows \
              recomputed, {} evictions",
             responses.len(), gw.rejected_total(),
             c.hits.load(Ordering::Relaxed),
             c.misses.load(Ordering::Relaxed),
             100.0 * c.hit_rate(),
             c.reused_rows.load(Ordering::Relaxed),
             c.recomputed_rows.load(Ordering::Relaxed),
             c.evictions.load(Ordering::Relaxed));
    gw.shutdown();
    Ok(())
}

fn cmd_shard_worker(rest: &[String]) -> Result<()> {
    let cmd = Command::new(
        "shard-worker",
        "serve raw attention sub-batches for a sharded gateway")
        .opt("addr", Some("127.0.0.1:7979"), "bind address")
        .opt("workers", Some("0"),
             "solve pool size (0 = auto, 1 = sequential)")
        .opt("cache-rows", Some("0"),
             "KV-cache capacity in cached sequence rows (0 = unbounded)")
        .opt("cache-growth", Some("1.0"),
             "clustered re-cluster threshold (1.0 = exact every step)")
        .opt("cache-quant", Some("off"),
             "KV-panel storage: off | i8-head | i8-panel (i8 packs \
              ~4x more live sessions per cached byte; must match the \
              gateway's --cache-quant for uniform fleet numerics)");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let cache_rows = args.get_usize("cache-rows", 0)?;
    let cache = Arc::new(attention::KvCache::new(
        attention::KvCacheOptions {
            capacity_rows: if cache_rows == 0 { usize::MAX }
                           else { cache_rows },
            growth: args.get_f64("cache-growth", 1.0)?,
            quant: parse_cache_quant(&args)?,
        }));
    let engine = Arc::new(attention::ShardEngine::with_cache(
        args.get_usize("workers", 0)?, cache));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let addr = args.get_or("addr", "127.0.0.1:7979");
    println!("shard worker serving on {addr} (ctrl-c to stop)");
    clustered_transformers::server::serve_shard_worker(
        engine, &addr, stop, |a| println!("bound {a}"))
}

fn cmd_oracle(rest: &[String]) -> Result<()> {
    use clustered_transformers::oracle;
    let action = rest.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if rest.is_empty() { &[][..] } else { &rest[1..] };
    match action {
        "record" => cmd_oracle_record(rest, /*bless=*/ false),
        "bless" => cmd_oracle_record(rest, /*bless=*/ true),
        "replay" => cmd_oracle_replay(rest),
        "perf-gate" => cmd_oracle_perf_gate(rest),
        _ => {
            println!(
                "ct oracle — golden-trace regression harness \
                 (docs/TESTING.md)\n\
                 actions:\n\
                 \x20 record     record standard-suite fixtures that are \
                 missing (--force: all)\n\
                 \x20 replay     re-run the recorded suite on this build, \
                 diff bit-exactly,\n\
                 \x20            write {}\n\
                 \x20 bless      re-record every fixture in place \
                 (--bench: also copy fresh\n\
                 \x20            BENCH_*.json into {})\n\
                 \x20 perf-gate  compare fresh BENCH_*.json against the \
                 blessed baselines",
                oracle::default_report_path().display(),
                oracle::default_baseline_dir().display());
            Ok(())
        }
    }
}

fn cmd_oracle_record(rest: &[String], bless: bool) -> Result<()> {
    use clustered_transformers::oracle;
    let cmd = if bless {
        Command::new("oracle bless",
                     "re-record the fixture suite on this build")
            .flag("bench",
                  "also bless perf baselines: copy the repo root's fresh \
                   BENCH_*.json files into bench-baselines/")
    } else {
        Command::new("oracle record",
                     "record standard-suite fixtures (missing-only by \
                      default)")
            .flag("force", "re-record fixtures that already exist")
    }
    .opt("fixtures", None,
         "fixture directory (default <repo>/oracle/fixtures)");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let dir = args.get("fixtures")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_fixture_dir);
    let force = bless || args.flag("force");
    let recorded =
        oracle::record_suite(&dir, &oracle::standard_suite(), force)?;
    if recorded.is_empty() {
        println!("all fixtures present in {} — nothing recorded \
                  (use `ct oracle bless` to re-record)", dir.display());
    } else {
        for name in &recorded {
            println!("recorded {name}");
        }
        println!("{} fixture(s) written to {}", recorded.len(),
                 dir.display());
    }
    if bless && args.flag("bench") {
        let root = find_repo_root();
        let baselines = oracle::default_baseline_dir();
        std::fs::create_dir_all(&baselines)?;
        let mut copied = 0;
        let mut names: Vec<String> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().to_string())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect();
        names.sort();
        for name in names {
            std::fs::copy(root.join(&name), baselines.join(&name))?;
            println!("blessed baseline {name}");
            copied += 1;
        }
        if copied == 0 {
            println!("no BENCH_*.json at {} — run the benches first \
                      (cargo bench, or CT_SMOKE=1 for the quick pass)",
                     root.display());
        }
    }
    Ok(())
}

fn cmd_oracle_replay(rest: &[String]) -> Result<()> {
    use clustered_transformers::oracle;
    let cmd = Command::new("oracle replay",
                           "replay recorded fixtures against this build")
        .opt("fixtures", None,
             "fixture directory (default <repo>/oracle/fixtures)")
        .opt("policy", None,
             "tolerance policy path (default \
              <repo>/oracle/tolerance-policy.json)")
        .opt("report", None,
             "report output path (default <repo>/oracle-report.json)")
        .flag("inject-perturbation",
              "self-test: flip one output bit of the first fixture — \
               the run must go red (CI proves the harness can fail)");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let dir = args.get("fixtures")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_fixture_dir);
    let policy_path = args.get("policy")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_policy_path);
    let report_path = args.get("report")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_report_path);
    let policy = oracle::TolerancePolicy::load(&policy_path)?;
    let names = oracle::Manifest::load(&dir)?.fixtures;
    if names.is_empty() {
        return Err(anyhow!(
            "no fixtures in {} — run `ct oracle record` first",
            dir.display()));
    }
    let perturb = args.flag("inject-perturbation");
    let report = oracle::replay_suite(&dir, &names, &policy, perturb);
    report.write(&report_path)?;
    for f in &report.fixtures {
        println!("{}  {}", if f.passed { "pass" } else { "FAIL" },
                 f.name);
        for msg in f.failures.iter().chain(&f.notes) {
            println!("      {msg}");
        }
    }
    println!("report: {}", report_path.display());
    if report.passed() {
        println!("oracle: green ({} fixtures bit-exact)",
                 report.fixtures.len());
        Ok(())
    } else {
        Err(anyhow!("oracle: RED — see {}", report_path.display()))
    }
}

fn cmd_oracle_perf_gate(rest: &[String]) -> Result<()> {
    use clustered_transformers::oracle;
    let cmd = Command::new(
        "oracle perf-gate",
        "fail on bench throughput regressions vs blessed baselines")
        .opt("fresh", None,
             "directory holding fresh BENCH_*.json (default repo root)")
        .opt("baselines", None,
             "baseline directory (default <repo>/bench-baselines)")
        .opt("policy", None,
             "tolerance policy path (default \
              <repo>/oracle/tolerance-policy.json)")
        .opt("report", None,
             "oracle report to merge the verdict into (default \
              <repo>/oracle-report.json)")
        .flag("self-check",
              "first prove the gate can fail on fabricated numbers, \
               then run it for real")
        .flag("strict",
              "exit nonzero when any suite was skipped on a bootstrap \
               baseline — refuse to green-light an un-armed gate");
    let args = cmd.parse(rest)?;
    init_logging(true);
    let policy_path = args.get("policy")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_policy_path);
    let policy = oracle::TolerancePolicy::load(&policy_path)?;
    if args.flag("self-check") {
        oracle::self_check(policy.max_bench_regression)?;
        println!("perf-gate self-check: red path verified");
    }
    let fresh = args.get("fresh")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(find_repo_root);
    let baselines = args.get("baselines")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_baseline_dir);
    let gate = oracle::run_perf_gate(&fresh, &baselines,
                                     policy.max_bench_regression)?;
    for b in &gate.benches {
        println!("{:22} {}", b.status, b.file);
        for note in &b.notes {
            println!("      {note}");
        }
    }
    // bootstrap baselines gate nothing: say so loudly, one line per
    // suite, so a quietly un-armed gate can't pass for a real one
    let boots = gate.bootstrap_skips();
    for file in &boots {
        println!("SKIPPED (bootstrap baseline): {file}");
    }
    let report_path = args.get("report")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(oracle::default_report_path);
    let ok = oracle::OracleReport::merge_perf_into(
        &report_path, gate.to_value(), gate.passed())?;
    println!("report: {}", report_path.display());
    if gate.passed() {
        println!("perf gate: pass (tolerance {:.0}%)",
                 policy.max_bench_regression * 100.0);
        if !ok {
            return Err(anyhow!("perf gate passed but {} is red from \
                                the replay phase",
                               report_path.display()));
        }
        if args.flag("strict") && !boots.is_empty() {
            return Err(anyhow!(
                "perf gate (--strict): {} suite(s) skipped on \
                 bootstrap baselines — record real baselines to arm \
                 the gate", boots.len()));
        }
        Ok(())
    } else {
        Err(anyhow!("perf gate: FAIL — rows/sec regressed more than \
                     {:.0}% (see {})",
                    policy.max_bench_regression * 100.0,
                    report_path.display()))
    }
}

fn cmd_lint(rest: &[String]) -> Result<()> {
    use clustered_transformers::lint;
    let cmd = Command::new(
        "lint",
        "contract-aware static analysis over the crate's own sources \
         (determinism, panic-safety, wire-stability, doc drift)")
        .opt("root", None, "repo root (default: discovered)")
        .opt("report", None,
             "report output path (default <repo>/lint-report.json)")
        .flag("json", "print the full JSON report to stdout")
        .flag("self-check",
              "inject synthetic probe violations and require every \
               rule to fire — a healthy linter exits nonzero (CI \
               asserts that, mirroring the oracle perturbation test)");
    let args = cmd.parse(rest)?;
    init_logging(false);
    let root = args.get("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(find_repo_root);
    let report_path = args.get("report")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(lint::default_report_path);

    if args.flag("self-check") {
        let sc = lint::self_check(&root)?;
        if !sc.missed.is_empty() {
            // broken scanner: report success (exit 0) so the inverted
            // CI assertion `if ct lint --self-check; then fail` trips
            println!("lint self-check FAILED — rules that did not \
                      fire on the injected probes: {}",
                     sc.missed.join(", "));
            return Ok(());
        }
        println!("{}", sc.report.console());
        return Err(anyhow!(
            "lint self-check: red path verified — {} injected \
             violation(s) detected across every rule", sc.injected));
    }

    let report = lint::run(&root)?;
    std::fs::write(&report_path, report.render())?;
    if args.flag("json") {
        print!("{}", report.render());
    } else {
        print!("{}", report.console());
    }
    println!("report: {}", report_path.display());
    if report.passed() {
        Ok(())
    } else {
        Err(anyhow!("ct lint: {} violation(s) — fix them or add a \
                     reasoned `ct-lint: allow(...)` (see \
                     docs/TESTING.md)", report.violations.len()))
    }
}

fn cmd_bench_attn(rest: &[String]) -> Result<()> {
    let cmd = Command::new("bench-attn", "native attention quick timing")
        .opt("n", Some("2048"), "sequence length")
        .opt("dk", Some("64"), "head dim")
        .opt("clusters", Some("100"), "C")
        .opt("topk", Some("32"), "k")
        .opt("variant", None,
             "bench a single kernel by registry name (e.g. \
              i-clustered-64); default: the paper's comparison set");
    let args = cmd.parse(rest)?;
    let n = args.get_usize("n", 2048)?;
    let dk = args.get_usize("dk", 64)?;
    let c = args.get_usize("clusters", 100)?;
    let k = args.get_usize("topk", 32)?;
    let mut rng = prng::Xoshiro256::new(0);
    let q = tensor::Matrix::randn(n, dk, &mut rng);
    let kk = tensor::Matrix::randn(n, dk, &mut rng);
    let v = tensor::Matrix::randn(n, dk, &mut rng);
    let mut table = benchlib::Table::new(
        &format!("native attention, N={n} Dk={dk}"),
        &["variant", "mean", "speedup vs full"],
    );
    let variants = match args.get("variant") {
        // name-keyed registry path: resolve paper notation directly
        Some(name) => vec![attention::Variant::parse(name).ok_or_else(
            || anyhow!("unknown kernel {name:?}; registered families: {}",
                       attention::kernel_families().join(", ")))?],
        None => vec![
            attention::Variant::Full,
            attention::Variant::Clustered { clusters: c, bits: 63,
                                            iters: 10 },
            attention::Variant::ImprovedClustered {
                clusters: c, bits: 63, iters: 10, topk: k },
            attention::Variant::Lsh { rounds: 1, chunk: 32 },
            attention::Variant::Lsh { rounds: 4, chunk: 32 },
        ],
    };
    let mut full_time = None;
    for var in &variants {
        let mut rng2 = prng::Xoshiro256::new(1);
        let ctx = clustered_transformers::exec::ExecCtx::sequential();
        let st = benchlib::quick(|| {
            let p = attention::AttnProblem::new(&q, &kk, &v);
            let _ = attention::solve(var, &p, &mut rng2, &ctx);
        });
        if matches!(var, attention::Variant::Full) {
            full_time = Some(st.mean_s);
        }
        let speedup = full_time.map(|f| f / st.mean_s).unwrap_or(1.0);
        table.row(vec![var.name(), benchlib::fmt_time(st.mean_s),
                       format!("{speedup:.2}x")]);
    }
    table.emit();
    Ok(())
}
