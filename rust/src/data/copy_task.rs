//! Masked copy task (paper §C.2, fig. 5).
//!
//! A random sequence `w ∈ {1..S}^L` is laid out as `0 w 0 w` (0 is the
//! separator).  A fraction of symbols is replaced by MASK in the first
//! half and a *different* set in the second half, so the target is always
//! reconstructible by attending to the twin position.  Token ids:
//! `0` separator, `1..=S` symbols, `S+1` MASK.

use super::{batch_rng, Split};
use crate::prng::Xoshiro256;

#[derive(Debug, Clone)]
pub struct CopyTask {
    pub seq_len: usize,   // N = 2L + 2
    pub n_symbols: usize, // S (paper: 10)
    pub mask_frac: f64,   // paper: 0.2
    pub seed: u64,
}

/// One batch in the `tok` program layout.
#[derive(Debug, Clone)]
pub struct CopyBatch {
    /// (B·N) input token ids
    pub x: Vec<i32>,
    /// (B·N) target token ids (the un-masked sequence)
    pub y: Vec<i32>,
    /// (B·N) loss weights: 1.0 exactly on masked positions
    pub w: Vec<f32>,
    pub batch: usize,
    pub seq_len: usize,
}

impl CopyTask {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        assert!(seq_len >= 4 && seq_len % 2 == 0,
                "seq_len must be even (0w0w layout)");
        Self { seq_len, n_symbols: 10, mask_frac: 0.2, seed }
    }

    pub fn half_len(&self) -> usize {
        self.seq_len / 2 - 1 // L
    }

    pub fn mask_token(&self) -> i32 {
        self.n_symbols as i32 + 1
    }

    fn sample_one(&self, rng: &mut Xoshiro256, x: &mut [i32], y: &mut [i32],
                  w: &mut [f32]) {
        let l = self.half_len();
        let n = self.seq_len;
        // target 0 w 0 w
        y[0] = 0;
        y[l + 1] = 0;
        for i in 0..l {
            let sym = rng.range(1, self.n_symbols as i64 + 1) as i32;
            y[1 + i] = sym;
            y[l + 2 + i] = sym;
        }
        x.copy_from_slice(y);
        w.iter_mut().for_each(|v| *v = 0.0);
        // mask a fraction of the first half and a DIFFERENT set of the
        // second half so every symbol stays recoverable
        let n_masked = ((l as f64) * self.mask_frac).ceil() as usize;
        let n_masked = n_masked.clamp(1, l.saturating_sub(1).max(1));
        let first = rng.sample_indices(l, n_masked.min(l));
        let mut remaining: Vec<usize> =
            (0..l).filter(|i| !first.contains(i)).collect();
        rng.shuffle(&mut remaining);
        let second: Vec<usize> =
            remaining.into_iter().take(n_masked.min(l)).collect();
        for &i in &first {
            x[1 + i] = self.mask_token();
            w[1 + i] = 1.0;
        }
        for &i in &second {
            x[l + 2 + i] = self.mask_token();
            w[l + 2 + i] = 1.0;
        }
        let _ = n;
    }

    /// Deterministic batch for (split, index).
    pub fn batch(&self, split: Split, index: u64, batch: usize) -> CopyBatch {
        let mut rng = batch_rng(self.seed, split, index);
        let n = self.seq_len;
        let mut out = CopyBatch {
            x: vec![0; batch * n],
            y: vec![0; batch * n],
            w: vec![0.0; batch * n],
            batch,
            seq_len: n,
        };
        for b in 0..batch {
            let (s, e) = (b * n, (b + 1) * n);
            self.sample_one(&mut rng, &mut out.x[s..e], &mut out.y[s..e],
                            &mut out.w[s..e]);
        }
        out
    }
}

/// Masked-position accuracy given logits (B·N·V row-major).
pub fn masked_accuracy(batch: &CopyBatch, logits: &[f32], vocab: usize)
                       -> f64 {
    let n = batch.seq_len;
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in 0..batch.batch {
        for i in 0..n {
            let pos = b * n + i;
            if batch.w[pos] == 0.0 {
                continue;
            }
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            total += 1;
            if argmax as i32 == batch.y[pos] {
                correct += 1;
            }
        }
    }
    if total == 0 { 1.0 } else { correct as f64 / total as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_0w0w_and_reconstructible() {
        let task = CopyTask::new(64, 7);
        let b = task.batch(Split::Train, 0, 4);
        let l = task.half_len();
        for s in 0..4 {
            let y = &b.y[s * 64..(s + 1) * 64];
            let x = &b.x[s * 64..(s + 1) * 64];
            assert_eq!(y[0], 0);
            assert_eq!(y[l + 1], 0);
            for i in 0..l {
                assert_eq!(y[1 + i], y[l + 2 + i], "halves must match");
                assert!((1..=10).contains(&y[1 + i]));
                // reconstructible: never masked at BOTH twin positions
                let m1 = x[1 + i] == task.mask_token();
                let m2 = x[l + 2 + i] == task.mask_token();
                assert!(!(m1 && m2), "symbol {i} masked twice");
            }
        }
    }

    #[test]
    fn weights_mark_exactly_the_masked_positions() {
        let task = CopyTask::new(32, 9);
        let b = task.batch(Split::Valid, 3, 8);
        for pos in 0..b.x.len() {
            let masked = b.x[pos] == task.mask_token();
            assert_eq!(b.w[pos] == 1.0, masked, "pos {pos}");
        }
        assert!(b.w.iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn batches_are_deterministic_and_split_dependent() {
        let task = CopyTask::new(32, 1);
        let a = task.batch(Split::Train, 5, 2);
        let b = task.batch(Split::Train, 5, 2);
        let c = task.batch(Split::Valid, 5, 2);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn masked_accuracy_perfect_oracle() {
        let task = CopyTask::new(16, 2);
        let b = task.batch(Split::Test, 0, 2);
        let vocab = 11;
        // oracle logits: one-hot of the target
        let mut logits = vec![0f32; b.x.len() * vocab];
        for pos in 0..b.x.len() {
            logits[pos * vocab + b.y[pos] as usize] = 10.0;
        }
        assert_eq!(masked_accuracy(&b, &logits, vocab), 1.0);
    }
}
