//! Synthetic workload generators (the data substrate, DESIGN.md §2).
//!
//! All generators are deterministic functions of a seed via
//! [`crate::prng::Xoshiro256`], so every experiment is exactly
//! reproducible.  Batches are emitted in the flat layouts the AOT
//! manifest declares (`programs.py` docstring).

use crate::prng::Xoshiro256;

pub mod asr;
pub mod copy_task;
pub mod glue;

pub use asr::{AsrBatch, AsrCorpus, AsrSpec};
pub use copy_task::{CopyBatch, CopyTask};
pub use glue::{GlueBatch, GlueTask, SpanBatch};

/// A dataset draws reproducible batches by (split, index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
}

impl Split {
    pub fn salt(self) -> u64 {
        match self {
            Split::Train => 0x7261696e,
            Split::Valid => 0x76616c69,
            Split::Test => 0x74657374,
        }
    }
}

/// Stream-id for a (seed, split, batch) triple.
pub fn batch_rng(seed: u64, split: Split, batch_idx: u64) -> Xoshiro256 {
    Xoshiro256::new(seed).fold_in(split.salt()).fold_in(batch_idx)
}
