//! GLUE/SQuAD-analog synthetic tasks (Table 4 substitute — DESIGN.md §2).
//!
//! Five task families over a 32-token vocabulary, chosen so that solving
//! them requires the attention patterns the paper highlights:
//!
//!  - `sst2`  : majority sentiment — local, easy (bag-of-words suffices)
//!  - `mrpc`  : are the two halves permutations of each other — global
//!  - `qnli`  : does the context contain the 3-gram query pattern
//!  - `rte`   : is the second half's vocabulary a subset of the first's
//!  - `squad` : span extraction — *sparse, pointer-like* attention, the
//!              case where plain clustered attention collapses (Table 4)
//!
//! Token map: 0 = PAD/CLS, 1 = SEP, 2 = QMARK (query marker),
//! 3.. = content tokens.  Sentiment tasks treat even content tokens as
//! "positive" and odd as "negative".

use super::{batch_rng, Split};
use crate::prng::Xoshiro256;

pub const VOCAB: usize = 32;
pub const SEP: i32 = 1;
pub const QMARK: i32 = 2;
pub const FIRST_CONTENT: i64 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlueTask {
    Sst2,
    Mrpc,
    Qnli,
    Rte,
    Squad,
}

impl GlueTask {
    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "sst2" => Self::Sst2,
            "mrpc" => Self::Mrpc,
            "qnli" => Self::Qnli,
            "rte" => Self::Rte,
            "squad" => Self::Squad,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sst2 => "sst2",
            Self::Mrpc => "mrpc",
            Self::Qnli => "qnli",
            Self::Rte => "rte",
            Self::Squad => "squad",
        }
    }

    pub fn seq_len(&self) -> usize {
        match self {
            Self::Squad => 192,
            _ => 128,
        }
    }
}

/// Classification batch (`cls` layout).
#[derive(Debug, Clone)]
pub struct GlueBatch {
    pub x: Vec<i32>,    // (B·N)
    pub mask: Vec<f32>, // (B·N)
    pub y: Vec<i32>,    // (B,)
    pub batch: usize,
    pub seq_len: usize,
}

/// Span batch (`span` layout).
#[derive(Debug, Clone)]
pub struct SpanBatch {
    pub x: Vec<i32>,
    pub mask: Vec<f32>,
    pub ystart: Vec<i32>,
    pub yend: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

fn content(rng: &mut Xoshiro256) -> i32 {
    rng.range(FIRST_CONTENT, VOCAB as i64) as i32
}

fn fill_sample(task: GlueTask, rng: &mut Xoshiro256, x: &mut [i32],
               mask: &mut [f32]) -> (i32, i32, i32) {
    // returns (label, start, end) — classification uses label only
    let n = x.len();
    x.iter_mut().for_each(|v| *v = 0);
    let len = rng.range((n as i64) * 3 / 4, n as i64 + 1) as usize;
    mask.iter_mut().enumerate().for_each(|(i, m)| {
        *m = if i < len { 1.0 } else { 0.0 }
    });

    match task {
        GlueTask::Sst2 => {
            // label 1 iff strictly more even ("positive") content tokens
            let mut pos = 0i64;
            let mut neg = 0i64;
            for xi in x[..len].iter_mut() {
                let t = content(rng);
                *xi = t;
                if t % 2 == 0 { pos += 1 } else { neg += 1 }
            }
            // break ties deterministically by flipping one token
            if pos == neg {
                x[0] = if x[0] % 2 == 0 { x[0] + 1 } else { x[0] - 1 };
                neg += 1;
                let _ = neg;
            }
            let pos2 = x[..len].iter().filter(|t| *t % 2 == 0).count();
            ((pos2 * 2 > len) as i32, 0, 0)
        }
        GlueTask::Mrpc => {
            let half = (len - 1) / 2;
            let label = rng.coin(0.5) as i32;
            let mut a: Vec<i32> = (0..half).map(|_| content(rng)).collect();
            let mut b = a.clone();
            rng.shuffle(&mut b);
            if label == 0 {
                // corrupt one token: changing one element's value always
                // changes the multiset, so the halves stop being
                // permutations.  (A rejection loop "draw until not in a"
                // can run forever: long premises cover the whole content
                // vocabulary.)
                let pos = rng.below(half.max(1));
                let old = b[pos];
                let mut t = old + 1;
                if t >= VOCAB as i32 {
                    t = FIRST_CONTENT as i32;
                }
                b[pos] = t;
            }
            x[..half].copy_from_slice(&a);
            x[half] = SEP;
            x[half + 1..half + 1 + half].copy_from_slice(&b);
            let _ = &mut a;
            (label, 0, 0)
        }
        GlueTask::Qnli => {
            // query = 3-gram after QMARK; label 1 iff it occurs in context
            let qlen = 3usize;
            let ctx_start = qlen + 2;
            let q: Vec<i32> = (0..qlen).map(|_| content(rng)).collect();
            x[0] = QMARK;
            x[1..1 + qlen].copy_from_slice(&q);
            x[1 + qlen] = SEP;
            for xi in x[ctx_start..len].iter_mut() {
                *xi = content(rng);
            }
            let label = rng.coin(0.5) as i32;
            if label == 1 {
                let pos = ctx_start
                    + rng.below(len - ctx_start - qlen);
                x[pos..pos + qlen].copy_from_slice(&q);
                (1, 0, 0)
            } else {
                // ensure the q-gram does NOT occur
                for i in ctx_start..len - qlen + 1 {
                    if x[i..i + qlen] == q[..] {
                        x[i] = if x[i] + 1 >= VOCAB as i32 {
                            FIRST_CONTENT as i32
                        } else {
                            x[i] + 1
                        };
                    }
                }
                (0, 0, 0)
            }
        }
        GlueTask::Rte => {
            // premise = first half over a random sub-vocabulary;
            // hypothesis entailed iff its tokens ⊆ premise vocabulary
            let half = (len - 1) / 2;
            let sub: Vec<i32> = (0..6).map(|_| content(rng)).collect();
            for xi in x[..half].iter_mut() {
                *xi = sub[rng.below(sub.len())];
            }
            x[half] = SEP;
            let label = rng.coin(0.5) as i32;
            for xi in x[half + 1..half + 1 + half].iter_mut() {
                *xi = sub[rng.below(sub.len())];
            }
            if label == 0 {
                // inject an out-of-premise token
                let pos = half + 1 + rng.below(half.max(1));
                let mut t = content(rng);
                while sub.contains(&t) {
                    t = content(rng);
                }
                x[pos] = t;
            }
            (label, 0, 0)
        }
        GlueTask::Squad => {
            // question: QMARK + 2-gram needle + SEP; answer span = the
            // needle's (unique) occurrence in the context, plus the token
            // after it (span length 3)
            let qlen = 2usize;
            let ctx_start = qlen + 2;
            let needle: Vec<i32> = (0..qlen).map(|_| content(rng)).collect();
            x[0] = QMARK;
            x[1..1 + qlen].copy_from_slice(&needle);
            x[1 + qlen] = SEP;
            for xi in x[ctx_start..len].iter_mut() {
                *xi = content(rng);
            }
            // erase accidental needle matches, then plant one
            for i in ctx_start..len - qlen + 1 {
                if x[i..i + qlen] == needle[..] {
                    x[i] = if x[i] + 1 >= VOCAB as i32 {
                        FIRST_CONTENT as i32
                    } else {
                        x[i] + 1
                    };
                }
            }
            let pos = ctx_start + rng.below(len - ctx_start - qlen - 1);
            x[pos..pos + qlen].copy_from_slice(&needle);
            (0, pos as i32, (pos + qlen) as i32)
        }
    }
}

/// Deterministic classification batch.
pub fn cls_batch(task: GlueTask, seed: u64, split: Split, index: u64,
                 batch: usize) -> GlueBatch {
    assert!(task != GlueTask::Squad);
    let n = task.seq_len();
    // ct-lint: allow(det-seed-arith, reason = "task-stream decorrelation baked into recorded batches; rekeying via prng helpers would change every golden batch")
    let mut rng = batch_rng(seed ^ task.name().len() as u64, split, index)
        .fold_in(task as u64 + 100);
    let mut out = GlueBatch {
        x: vec![0; batch * n],
        mask: vec![0.0; batch * n],
        y: vec![0; batch],
        batch,
        seq_len: n,
    };
    for b in 0..batch {
        let (s, e) = (b * n, (b + 1) * n);
        let (label, _, _) = fill_sample(task, &mut rng, &mut out.x[s..e],
                                        &mut out.mask[s..e]);
        out.y[b] = label;
    }
    out
}

/// Deterministic span batch (squad-analog).
pub fn span_batch(seed: u64, split: Split, index: u64, batch: usize)
                  -> SpanBatch {
    let task = GlueTask::Squad;
    let n = task.seq_len();
    // ct-lint: allow(det-seed-arith, reason = "label-stream decorrelation baked into recorded batches; rekeying via prng helpers would change every golden batch")
    let mut rng = batch_rng(seed ^ 5, split, index).fold_in(999);
    let mut out = SpanBatch {
        x: vec![0; batch * n],
        mask: vec![0.0; batch * n],
        ystart: vec![0; batch],
        yend: vec![0; batch],
        batch,
        seq_len: n,
    };
    for b in 0..batch {
        let (s, e) = (b * n, (b + 1) * n);
        let (_, st, en) = fill_sample(task, &mut rng, &mut out.x[s..e],
                                      &mut out.mask[s..e]);
        out.ystart[b] = st;
        out.yend[b] = en;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sst2_label_matches_majority() {
        let b = cls_batch(GlueTask::Sst2, 0, Split::Train, 0, 16);
        for s in 0..16 {
            let row = &b.x[s * 128..(s + 1) * 128];
            let m = &b.mask[s * 128..(s + 1) * 128];
            let len = m.iter().filter(|&&v| v > 0.0).count();
            let pos = row[..len].iter().filter(|&&t| t % 2 == 0).count();
            assert_eq!(b.y[s], (pos * 2 > len) as i32);
        }
    }

    #[test]
    fn mrpc_positive_pairs_are_permutations() {
        let b = cls_batch(GlueTask::Mrpc, 1, Split::Train, 2, 32);
        for s in 0..32 {
            let row = &b.x[s * 128..(s + 1) * 128];
            let m = &b.mask[s * 128..(s + 1) * 128];
            let len = m.iter().filter(|&&v| v > 0.0).count();
            let half = (len - 1) / 2;
            let mut a: Vec<i32> = row[..half].to_vec();
            let mut c: Vec<i32> = row[half + 1..half + 1 + half].to_vec();
            a.sort_unstable();
            c.sort_unstable();
            assert_eq!(b.y[s] == 1, a == c, "sample {s}");
        }
    }

    #[test]
    fn qnli_label_matches_substring_presence() {
        let b = cls_batch(GlueTask::Qnli, 2, Split::Valid, 1, 32);
        for s in 0..32 {
            let row = &b.x[s * 128..(s + 1) * 128];
            let m = &b.mask[s * 128..(s + 1) * 128];
            let len = m.iter().filter(|&&v| v > 0.0).count();
            let q = &row[1..4];
            let ctx = &row[5..len];
            let found = ctx.windows(3).any(|w| w == q);
            assert_eq!(b.y[s] == 1, found, "sample {s}");
        }
    }

    #[test]
    fn rte_label_matches_subset_relation() {
        let b = cls_batch(GlueTask::Rte, 3, Split::Train, 4, 32);
        for s in 0..32 {
            let row = &b.x[s * 128..(s + 1) * 128];
            let m = &b.mask[s * 128..(s + 1) * 128];
            let len = m.iter().filter(|&&v| v > 0.0).count();
            let half = (len - 1) / 2;
            let prem: Vec<i32> = row[..half].to_vec();
            let subset = row[half + 1..half + 1 + half]
                .iter()
                .all(|t| prem.contains(t));
            assert_eq!(b.y[s] == 1, subset, "sample {s}");
        }
    }

    #[test]
    fn squad_span_contains_the_needle_uniquely() {
        let b = span_batch(4, Split::Train, 0, 32);
        let n = 192;
        for s in 0..32 {
            let row = &b.x[s * n..(s + 1) * n];
            let needle = &row[1..3];
            let st = b.ystart[s] as usize;
            let en = b.yend[s] as usize;
            assert_eq!(&row[st..st + 2], needle);
            assert_eq!(en, st + 2);
            // unique occurrence in context
            let m = &b.mask[s * n..(s + 1) * n];
            let len = m.iter().filter(|&&v| v > 0.0).count();
            let hits = row[4..len]
                .windows(2)
                .filter(|w| *w == needle)
                .count();
            assert_eq!(hits, 1, "sample {s}");
        }
    }

    #[test]
    fn labels_are_roughly_balanced() {
        for task in [GlueTask::Mrpc, GlueTask::Qnli, GlueTask::Rte] {
            let b = cls_batch(task, 9, Split::Train, 0, 64);
            let ones: i32 = b.y.iter().sum();
            assert!((16..=48).contains(&ones), "{task:?}: {ones}/64");
        }
    }
}
