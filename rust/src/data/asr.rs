//! Synthetic ASR corpus (WSJ / Switchboard analogs — DESIGN.md §2).
//!
//! Each phoneme has a fixed Gaussian prototype in feature space; an
//! utterance renders a random phone string to "filterbank" frames with
//! per-phone duration jitter, coarticulation smoothing and additive
//! noise, yielding a CTC-learnable monotonic seq→label problem with the
//! same shape as the paper's WSJ/SWB pipelines (variable-length inputs
//! ~10 frames/label, padded to the bucket length).

use super::{batch_rng, Split};
use crate::prng::Xoshiro256;

/// Corpus hyper-parameters.
#[derive(Debug, Clone)]
pub struct AsrSpec {
    pub n_phones: usize,   // label vocabulary (blank excluded)
    pub d_feat: usize,     // feature dim (40 = filterbank-analog)
    pub min_dur: usize,    // min frames per phone
    pub max_dur: usize,    // max frames per phone
    pub noise: f32,        // additive feature noise σ
    pub seq_len: usize,    // padded frame budget N
    pub max_labels: usize, // padded label budget
    pub seed: u64,
}

impl AsrSpec {
    /// WSJ-analog: 20 phones, mild noise (paper: N̄ = 780, we use 256).
    pub fn wsj(seed: u64) -> Self {
        Self { n_phones: 20, d_feat: 40, min_dur: 4, max_dur: 12,
               noise: 0.3, seq_len: 256, max_labels: 48, seed }
    }

    /// SWB-analog: more phones, longer and noisier (telephone speech).
    pub fn swb(seed: u64) -> Self {
        Self { n_phones: 40, d_feat: 40, min_dur: 3, max_dur: 10,
               noise: 0.5, seq_len: 384, max_labels: 64, seed }
    }
}

/// The rendered corpus: phone prototypes are fixed per corpus seed.
#[derive(Debug, Clone)]
pub struct AsrCorpus {
    pub spec: AsrSpec,
    /// (n_phones × d_feat) prototype vectors
    protos: Vec<f32>,
}

/// Batch in the `ctc` program layout.
#[derive(Debug, Clone)]
pub struct AsrBatch {
    /// (B·N·D) features, padded with zeros
    pub x: Vec<f32>,
    /// (B,) valid frame counts
    pub xlen: Vec<i32>,
    /// (B·Lmax) labels (1-based), zero-padded
    pub y: Vec<i32>,
    /// (B,) label counts
    pub ylen: Vec<i32>,
    pub batch: usize,
}

impl AsrCorpus {
    pub fn new(spec: AsrSpec) -> Self {
        let mut rng = Xoshiro256::new(spec.seed).fold_in(0x70726f746f);
        // well-separated prototypes: unit-norm gaussian directions × gain
        let mut protos = rng.normal_vec(spec.n_phones * spec.d_feat);
        for p in 0..spec.n_phones {
            let row = &mut protos[p * spec.d_feat..(p + 1) * spec.d_feat];
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            row.iter_mut().for_each(|v| *v *= 2.0 / norm.max(1e-6));
        }
        Self { spec, protos }
    }

    pub fn proto(&self, phone: usize) -> &[f32] {
        &self.protos[phone * self.spec.d_feat..(phone + 1) * self.spec.d_feat]
    }

    /// Render one utterance; returns (frames, labels).
    fn sample_one(&self, rng: &mut Xoshiro256) -> (Vec<f32>, Vec<i32>) {
        let s = &self.spec;
        let mut labels = Vec::new();
        let mut frames: Vec<f32> = Vec::new();
        // draw phones until the frame budget would overflow
        loop {
            let dur = rng.range(s.min_dur as i64, s.max_dur as i64 + 1)
                as usize;
            if frames.len() / s.d_feat + dur > s.seq_len
                || labels.len() + 1 > s.max_labels
            {
                break;
            }
            let phone = rng.below(s.n_phones);
            labels.push(phone as i32 + 1); // 1-based, 0 = blank
            let proto = self.proto(phone);
            for f in 0..dur {
                // onset/offset taper emulates coarticulation
                let env = if f == 0 || f == dur - 1 { 0.6 } else { 1.0 };
                for d in 0..s.d_feat {
                    frames.push(env * proto[d] + s.noise * rng.normal_f32());
                }
            }
            if labels.len() >= 3 && rng.coin(0.08) {
                break; // natural utterance-length variation
            }
        }
        (frames, labels)
    }

    /// Deterministic batch for (split, index) in the ctc layout.
    pub fn batch(&self, split: Split, index: u64, batch: usize) -> AsrBatch {
        let s = &self.spec;
        let mut rng = batch_rng(s.seed, split, index);
        let mut out = AsrBatch {
            x: vec![0.0; batch * s.seq_len * s.d_feat],
            xlen: vec![0; batch],
            y: vec![0; batch * s.max_labels],
            ylen: vec![0; batch],
            batch,
        };
        for b in 0..batch {
            let (frames, labels) = self.sample_one(&mut rng);
            let t = frames.len() / s.d_feat;
            out.xlen[b] = t as i32;
            out.ylen[b] = labels.len() as i32;
            let xoff = b * s.seq_len * s.d_feat;
            out.x[xoff..xoff + frames.len()].copy_from_slice(&frames);
            let yoff = b * s.max_labels;
            out.y[yoff..yoff + labels.len()].copy_from_slice(&labels);
        }
        out
    }
}

/// Greedy CTC decode of one sample's logits (T×V, blank = 0): argmax per
/// frame, collapse repeats, strip blanks.
pub fn ctc_greedy_decode(logits: &[f32], t_valid: usize, vocab: usize)
                         -> Vec<i32> {
    let mut out = Vec::new();
    let mut prev = -1i32;
    for t in 0..t_valid {
        let row = &logits[t * vocab..(t + 1) * vocab];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        if arg != prev && arg != 0 {
            out.push(arg);
        }
        prev = arg;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let c1 = AsrCorpus::new(AsrSpec::wsj(3));
        let c2 = AsrCorpus::new(AsrSpec::wsj(3));
        assert_eq!(c1.protos, c2.protos);
        let b1 = c1.batch(Split::Train, 7, 2);
        let b2 = c2.batch(Split::Train, 7, 2);
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn batch_respects_budgets_and_layout() {
        let c = AsrCorpus::new(AsrSpec::wsj(1));
        let b = c.batch(Split::Train, 0, 8);
        for s in 0..8 {
            let t = b.xlen[s] as usize;
            let l = b.ylen[s] as usize;
            assert!(t <= 256 && l <= 48 && l >= 1);
            assert!(t >= 4 * l, "t={t} l={l}: need >= min_dur frames/label");
            // padding beyond xlen is zero
            let xoff = s * 256 * 40;
            assert!(b.x[xoff + t * 40..xoff + 256 * 40]
                .iter()
                .all(|&v| v == 0.0));
            // labels are 1-based
            assert!(b.y[s * 48..s * 48 + l].iter().all(|&p| p >= 1));
        }
    }

    #[test]
    fn prototypes_are_separated() {
        let c = AsrCorpus::new(AsrSpec::wsj(2));
        for a in 0..5 {
            for b in 0..5 {
                if a == b {
                    continue;
                }
                let d: f32 = c
                    .proto(a)
                    .iter()
                    .zip(c.proto(b))
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!(d.sqrt() > 1.0, "phones {a},{b} too close");
            }
        }
    }

    #[test]
    fn greedy_decode_collapses_and_strips() {
        // frames: blank,1,1,blank,2,2,1 -> [1,2,1]
        let seq = [0, 1, 1, 0, 2, 2, 1];
        let vocab = 3;
        let mut logits = vec![0f32; seq.len() * vocab];
        for (t, &s) in seq.iter().enumerate() {
            logits[t * vocab + s as usize] = 5.0;
        }
        assert_eq!(ctc_greedy_decode(&logits, seq.len(), vocab),
                   vec![1, 2, 1]);
    }

    #[test]
    fn oracle_features_decode_to_labels() {
        // Sanity: with zero noise the nearest-prototype classifier
        // recovers the phone string, so the task is learnable.
        let mut spec = AsrSpec::wsj(5);
        spec.noise = 0.0;
        let c = AsrCorpus::new(spec);
        let b = c.batch(Split::Train, 1, 1);
        let t = b.xlen[0] as usize;
        let l = b.ylen[0] as usize;
        // classify each frame by nearest prototype, collapse repeats
        let mut decoded = Vec::new();
        let mut prev = -1i32;
        for f in 0..t {
            let frame = &b.x[f * 40..(f + 1) * 40];
            let (mut best_d, mut best_p) = (f32::INFINITY, 0usize);
            for p in 0..c.spec.n_phones {
                let d: f32 = frame
                    .iter()
                    .zip(c.proto(p))
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                if d < best_d {
                    best_d = d;
                    best_p = p;
                }
            }
            let lab = best_p as i32 + 1;
            if lab != prev {
                decoded.push(lab);
                prev = lab;
            }
        }
        // the taper can duplicate boundaries; dedup again conservatively
        decoded.dedup();
        let want: Vec<i32> = b.y[..l].to_vec();
        assert_eq!(decoded, want);
    }
}
