//! ct-contract: bit-exact
//!
//! Oracle top-k baseline (paper §4.1): exact logits, keep only the k
//! largest per query — the upper bound any top-k approximation can reach.
//!
//! The per-query scan partitions over output rows on the ctx pool; the
//! logits scratch is allocated once per worker chunk instead of once
//! per call site, and each row's top-k + softmax reduction stays inside
//! one worker — parallel output is bit-identical to sequential.

use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, softmax_inplace, topk_indices, Matrix};

use super::{AttentionKernel, AttnProblem, Cost};

pub fn oracle_top_attention(q: &Matrix, k: &Matrix, v: &Matrix, topk: usize)
                            -> Matrix {
    oracle_top_attention_ctx(q, k, v, topk, &ExecCtx::sequential())
}

/// [`oracle_top_attention`] over the ctx pool.
pub fn oracle_top_attention_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                                topk: usize, ctx: &ExecCtx) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let dv = v.cols;
    let mut out = Matrix::zeros(q.rows, dv);
    par_rows(ctx, &mut out.data, q.rows, dv, |range, chunk| {
        let mut logits = vec![0f32; k.rows]; // one scratch per chunk
        for (off, i) in range.enumerate() {
            for j in 0..k.rows {
                logits[j] = dot(q.row(i), k.row(j)) * scale;
            }
            let idx = topk_indices(&logits, topk);
            let mut w: Vec<f32> = idx.iter().map(|&j| logits[j]).collect();
            softmax_inplace(&mut w);
            let orow = &mut chunk[off * dv..(off + 1) * dv];
            for (slot, &j) in idx.iter().enumerate() {
                axpy(orow, w[slot], v.row(j));
            }
        }
    });
    out
}

/// Oracle top-k kernel.
#[derive(Debug, Clone, Copy)]
pub struct OracleTopAttention {
    pub topk: usize,
}

impl AttentionKernel for OracleTopAttention {
    fn name(&self) -> String {
        format!("oracle-top-{}", self.topk)
    }

    /// Masking = solving the valid-prefix sub-problem: the per-query
    /// logits scan covers only valid keys, so top-k can never select a
    /// padded key and the masked run is bit-identical to the unpadded
    /// run.  A `query_span` scans only the span rows (each row's
    /// logits/top-k/softmax is independent of every other row), so
    /// incremental decode costs O(m·N) and matches the full solve's
    /// span rows bit-for-bit.
    fn solve(&self, p: &AttnProblem<'_>, _rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "oracle-top does not support causal attention");
        let (q, k, v) = p.valid_qkv();
        if p.is_spanned() {
            let qs = p.span_q();
            return p.restore_span(oracle_top_attention_ctx(
                &qs, &k, &v, self.topk, ctx));
        }
        p.restore_rows(oracle_top_attention_ctx(&q, &k, &v, self.topk,
                                                ctx))
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost {
            flops: n64 * n64 * dk64 + n64 * (self.topk as u64) * dv64,
            // one logits row per worker, not an N×N matrix.  Unlike the
            // streaming kernels this path reads K in place (no packed
            // copy), so K does not appear in its *extra*-bytes account.
            bytes: 4 * n64,
        }
    }
}
