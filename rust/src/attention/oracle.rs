//! Oracle top-k baseline (paper §4.1): exact logits, keep only the k
//! largest per query — the upper bound any top-k approximation can reach.

use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, softmax_inplace, topk_indices, Matrix};

use super::{AttentionKernel, Cost};

pub fn oracle_top_attention(q: &Matrix, k: &Matrix, v: &Matrix, topk: usize)
                            -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut out = Matrix::zeros(q.rows, v.cols);
    let mut logits = vec![0f32; k.rows];
    for i in 0..q.rows {
        for j in 0..k.rows {
            logits[j] = dot(q.row(i), k.row(j)) * scale;
        }
        let idx = topk_indices(&logits, topk);
        let mut w: Vec<f32> = idx.iter().map(|&j| logits[j]).collect();
        softmax_inplace(&mut w);
        let orow = out.row_mut(i);
        for (slot, &j) in idx.iter().enumerate() {
            axpy(orow, w[slot], v.row(j));
        }
    }
    out
}

/// Oracle top-k kernel.
#[derive(Debug, Clone, Copy)]
pub struct OracleTopAttention {
    pub topk: usize,
}

impl AttentionKernel for OracleTopAttention {
    fn name(&self) -> String {
        format!("oracle-top-{}", self.topk)
    }

    fn run(&self, q: &Matrix, k: &Matrix, v: &Matrix,
           _rng: &mut Xoshiro256) -> Matrix {
        oracle_top_attention(q, k, v, self.topk)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost {
            flops: n64 * n64 * dk64 + n64 * (self.topk as u64) * dv64,
            bytes: 4 * n64 * n64,
        }
    }
}
