//! ct-contract: bit-exact
//!
//! Linear (kernelized) attention — "Transformers are RNNs"
//! (Katharopoulos et al., same authors as the source paper) — the sixth
//! kernel family, and the only one that supports **causal** problems.
//!
//! Softmax is replaced by a positive feature map φ(x) = elu(x) + 1
//! applied elementwise to queries and keys, which factorizes the
//! attention matrix:
//!
//! ```text
//! out_i = ( φ(q_i)ᵀ · S ) / ( φ(q_i) · z )
//!     S  = Σ_j φ(k_j) v_jᵀ      (Dk × Dv)
//!     z  = Σ_j φ(k_j)           (Dk)
//! ```
//!
//! Bidirectionally the sums run over every valid key; causally they run
//! over each row's own prefix `j ≤ i`, which makes attention an RNN
//! with the constant-size [`RecurrentState`] `(S, z)` as its hidden
//! state — the accumulator the KV-cache layer persists per session so a
//! decode step costs O(m·D²) regardless of history length.
//!
//! ## The recurrent bit-identity contract
//!
//! The cached decode path must reproduce the full causal recompute
//! **bit-for-bit**, so the accumulation order is pinned down once, in
//! [`RecurrentState`]: keys are absorbed in ascending row order, each
//! row elementwise with `a` (feature dim) ascending and `c` (value dim)
//! ascending inside `a`; emission contracts `a` ascending with the same
//! `1/den.max(1e-30)` guard the softmax kernels use.  Every consumer —
//! the causal solve here, the cache's recurrent hits, the naive
//! property-test reference — replays exactly that elementary order, so
//! where the state came from (one shot, incremental steps, a replayed
//! prefix on another worker) can never change an output bit.
//!
//! Parallelism follows the compute-core contract: output rows are
//! partitioned over the [`ExecCtx`] pool.  Causal workers replay the
//! key prefix below their range into a private accumulator first —
//! redundant arithmetic, zero cross-worker coupling — so the reduction
//! order per output row is independent of the worker count.

use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, Matrix};

use super::{AttentionKernel, AttnProblem, Cost};

/// The positive feature map φ(x) = elu(x) + 1 (strictly positive, so
/// denominators never vanish for a nonempty key prefix).
#[inline]
pub fn feature_map(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

/// The constant-size linear-attention accumulator `(S, z)`: everything
/// a causal row needs to know about the keys at or below it, in
/// `Dk·Dv + Dk` floats — per-token decode state that does **not** grow
/// with history length (contrast the KV cache's O(len) panels).
///
/// The elementary accumulation order (module docs) is part of the type's
/// contract: [`RecurrentState::absorb`] and [`RecurrentState::emit`] are
/// the *only* arithmetic every linear-attention consumer performs, which
/// is what makes cached decode bit-identical to the full recompute.
#[derive(Debug, Clone, PartialEq)]
pub struct RecurrentState {
    dk: usize,
    dv: usize,
    /// `S` (Dk × Dv), row-major: `s[a·Dv + c] = Σ_j φ(k_j)[a] · v_j[c]`.
    s: Vec<f32>,
    /// `z` (Dk): `z[a] = Σ_j φ(k_j)[a]`.
    z: Vec<f32>,
}

impl RecurrentState {
    /// Fresh zero state (the empty key prefix).
    pub fn new(dk: usize, dv: usize) -> Self {
        Self { dk, dv, s: vec![0.0; dk * dv], z: vec![0.0; dk] }
    }

    /// `(Dk, Dv)` geometry — cache entries check this before reuse.
    #[inline]
    pub fn dims(&self) -> (usize, usize) {
        (self.dk, self.dv)
    }

    /// Bytes this state occupies — the per-session per-head decode
    /// memory cost, constant in history length.
    pub fn state_bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * std::mem::size_of::<f32>()
    }

    /// Fold one key/value row into the accumulator.  Fixed elementary
    /// order — `a` ascending, `c` ascending within `a` — is the
    /// bit-identity contract shared by every caller.
    pub fn absorb(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.dk, "k row width");
        debug_assert_eq!(v_row.len(), self.dv, "v row width");
        for a in 0..self.dk {
            let f = feature_map(k_row[a]);
            // ct-lint: allow(det-float-accum, reason = "recurrent-state update; rows arrive in session order and features in ascending a, the pinned order the cache contract freezes")
            self.z[a] += f;
            axpy(&mut self.s[a * self.dv..(a + 1) * self.dv], f, v_row);
        }
    }

    /// Emit the output row for `q_row` against the current accumulator:
    /// `out = (φ(q)ᵀ·S) · (1 / (φ(q)·z).max(1e-30))`, contracting `a`
    /// ascending.  The guard mirrors the softmax kernels' zero-mass
    /// fallback (an empty prefix emits zeros).
    pub fn emit(&self, q_row: &[f32], out: &mut [f32]) {
        debug_assert_eq!(q_row.len(), self.dk, "q row width");
        debug_assert_eq!(out.len(), self.dv, "out row width");
        out.fill(0.0);
        let mut den = 0.0f32;
        for a in 0..self.dk {
            let f = feature_map(q_row[a]);
            // ct-lint: allow(det-float-accum, reason = "denominator contraction in ascending a, the documented pinned order")
            den += f * self.z[a];
            axpy(out, f, &self.s[a * self.dv..(a + 1) * self.dv]);
        }
        let inv = 1.0 / den.max(1e-30);
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// Bidirectional linear attention: one shared `(S, z)` over *all* keys,
/// then an independent emit per query row (partitioned over the ctx
/// pool — emission is read-only on the state, so worker count can't
/// move a bit).
pub fn linear_attention_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                            ctx: &ExecCtx) -> Matrix {
    assert_eq!(q.cols, k.cols, "q/k dim mismatch");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    let (n_q, dv) = (q.rows, v.cols);
    let mut out = Matrix::zeros(n_q, dv);
    if n_q == 0 || dv == 0 {
        return out;
    }
    let mut state = RecurrentState::new(k.cols, dv);
    for j in 0..k.rows {
        state.absorb(k.row(j), v.row(j));
    }
    par_rows(ctx, &mut out.data, n_q, dv, |range, chunk| {
        for r in range.clone() {
            state.emit(q.row(r), &mut chunk[(r - range.start) * dv..][..dv]);
        }
    });
    out
}

/// Causal linear attention emitting rows `span..n` (`span = 0` emits
/// every row): row `i` absorbs keys `0..=i` before emitting.
///
/// Workers each replay the key prefix below their range into a private
/// [`RecurrentState`] — the replayed arithmetic is the same ascending
/// sequence of f32 ops no matter which worker performs it, so the
/// output is bit-identical for any worker count (and to the
/// accumulator-carrying decode path, which skips the replay entirely).
pub fn causal_linear_attention_span_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                                        span: usize, ctx: &ExecCtx)
                                        -> Matrix {
    assert_eq!(q.cols, k.cols, "q/k dim mismatch");
    assert_eq!(q.rows, k.rows, "causal attention needs q/k of equal length");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    assert!(span <= q.rows, "span {span} out of 0..={}", q.rows);
    let (n, dv) = (q.rows, v.cols);
    let rows = n - span;
    let mut out = Matrix::zeros(rows, dv);
    if rows == 0 || dv == 0 {
        return out;
    }
    par_rows(ctx, &mut out.data, rows, dv, |range, chunk| {
        let mut state = RecurrentState::new(k.cols, dv);
        for j in 0..span + range.start {
            state.absorb(k.row(j), v.row(j));
        }
        for r in range.clone() {
            let i = span + r;
            state.absorb(k.row(i), v.row(i));
            state.emit(q.row(i), &mut chunk[(r - range.start) * dv..][..dv]);
        }
    });
    out
}

/// Kernelized linear attention (feature map `elu(x)+1`), bidirectional
/// and causal — O(N·Dk·Dv) instead of O(N²·D), and the only family with
/// a constant-size recurrent decode state.
#[derive(Debug, Clone, Copy, Default)]
pub struct LinearAttention;

impl AttentionKernel for LinearAttention {
    fn name(&self) -> String {
        "linear".into()
    }

    fn supports_causal(&self) -> bool {
        true
    }

    /// Masking = solving the valid-prefix sub-problem (the accumulators
    /// only ever absorb valid keys).  A bidirectional `query_span`
    /// genuinely prunes emission to the span rows against the shared
    /// full-key state; a causal span replays the key prefix and emits
    /// only rows `span..valid` — in both cases bit-identical to the
    /// same rows of the spanless solve, per the span contract.
    fn solve(&self, p: &AttnProblem<'_>, _rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        let (q, k, v) = p.valid_qkv();
        if p.causal {
            let out = causal_linear_attention_span_ctx(&q, &k, &v,
                                                       p.span_start(), ctx);
            return p.restore_span(out);
        }
        if p.is_spanned() {
            let qs = p.span_q();
            return p.restore_span(linear_attention_ctx(&qs, &k, &v, ctx));
        }
        p.restore_rows(linear_attention_ctx(&q, &k, &v, ctx))
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost {
            // absorb + emit are each ~2·Dk·(Dv+1) flops per row
            flops: 4 * n64 * dk64 * (dv64 + 1),
            // working set: one (S, z) accumulator per worker
            bytes: 4 * (dk64 * dv64 + dk64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::WorkerPool;
    use crate::tensor::dot;

    fn qkv(n: usize, dk: usize, dv: usize, seed: u64)
           -> (Matrix, Matrix, Matrix) {
        let mut rng = Xoshiro256::new(seed);
        (Matrix::randn(n, dk, &mut rng), Matrix::randn(n, dk, &mut rng),
         Matrix::randn(n, dv, &mut rng))
    }

    fn phi(row: &[f32]) -> Vec<f32> {
        row.iter().map(|&x| feature_map(x)).collect()
    }

    #[test]
    fn bidirectional_matches_the_explicit_weight_matrix() {
        // out_i = Σ_j w_ij v_j with w_ij = φq_i·φk_j / Σ_j φq_i·φk_j —
        // mathematically equal to the factorized path (float noise only)
        let (q, k, v) = qkv(23, 6, 5, 1);
        let got = linear_attention_ctx(&q, &k, &v, &ExecCtx::sequential());
        for i in 0..q.rows {
            let fq = phi(q.row(i));
            let ws: Vec<f32> =
                (0..k.rows).map(|j| dot(&fq, &phi(k.row(j)))).collect();
            let mass: f32 = ws.iter().sum();
            let mut want = vec![0.0f32; v.cols];
            for (j, &w) in ws.iter().enumerate() {
                axpy(&mut want, w / mass, v.row(j));
            }
            for (c, &w) in want.iter().enumerate() {
                let g = got.data[i * v.cols + c];
                assert!((g - w).abs() < 1e-4,
                        "row {i} col {c}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn causal_parallel_is_bit_identical_to_sequential() {
        let (q, k, v) = qkv(97, 8, 8, 2);
        let seq = causal_linear_attention_span_ctx(&q, &k, &v, 0,
                                                   &ExecCtx::sequential());
        for workers in [2, 3, 8] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            let par = causal_linear_attention_span_ctx(&q, &k, &v, 0, &ctx);
            assert!(par.bit_identical(&seq), "workers={workers}");
        }
    }

    #[test]
    fn causal_last_row_equals_the_bidirectional_last_row() {
        // row n-1 attends every key either way, and both paths absorb
        // keys 0..n ascending into the same accumulator — bit-identical
        let (q, k, v) = qkv(31, 4, 6, 3);
        let c = causal_linear_attention_span_ctx(&q, &k, &v, 0,
                                                 &ExecCtx::sequential());
        let b = linear_attention_ctx(&q, &k, &v, &ExecCtx::sequential());
        assert_eq!(c.row(30), b.row(30));
    }

    #[test]
    fn span_emits_the_same_bits_as_the_full_causal_solve() {
        let (q, k, v) = qkv(40, 5, 5, 4);
        let full = causal_linear_attention_span_ctx(&q, &k, &v, 0,
                                                    &ExecCtx::sequential());
        for span in [1, 17, 39] {
            let got = causal_linear_attention_span_ctx(
                &q, &k, &v, span, &ExecCtx::sequential());
            assert_eq!(got.rows, 40 - span);
            for r in 0..got.rows {
                assert_eq!(got.row(r), full.row(span + r), "span {span}");
            }
        }
    }

    #[test]
    fn incremental_absorb_matches_the_from_scratch_state() {
        // clone-and-continue (the cache's hit path) ≡ replay-from-zero
        let (_, k, v) = qkv(12, 4, 3, 5);
        let mut scratch = RecurrentState::new(4, 3);
        for j in 0..8 {
            scratch.absorb(k.row(j), v.row(j));
        }
        let mut carried = scratch.clone();
        for j in 8..12 {
            scratch.absorb(k.row(j), v.row(j));
            carried.absorb(k.row(j), v.row(j));
        }
        assert_eq!(scratch, carried);
        assert_eq!(carried.state_bytes(), (4 * 3 + 4) * 4);
    }

    #[test]
    fn masked_causal_solve_matches_the_unpadded_prefix() {
        let (q, k, v) = qkv(16, 4, 4, 6);
        let mut rng = Xoshiro256::new(0);
        let p = AttnProblem::new(&q, &k, &v)
            .with_valid_len(9)
            .with_causal(true);
        let got = LinearAttention.solve(&p, &mut rng, &ExecCtx::sequential());
        let (qp, kp, vp) = (q.row_prefix(9), k.row_prefix(9), v.row_prefix(9));
        let want = causal_linear_attention_span_ctx(&qp, &kp, &vp, 0,
                                                    &ExecCtx::sequential());
        assert_eq!((got.rows, got.cols), (16, 4));
        assert_eq!(&got.data[..9 * 4], &want.data[..]);
        assert!(got.data[9 * 4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn empty_prefix_emits_zero_rows_through_the_guard() {
        let mut rng = Xoshiro256::new(7);
        let q = Matrix::randn(4, 8, &mut rng);
        let k = Matrix::zeros(0, 8);
        let v = Matrix::zeros(0, 8);
        let out = linear_attention_ctx(&q, &k, &v, &ExecCtx::sequential());
        assert_eq!((out.rows, out.cols), (4, 8));
        assert!(out.data.iter().all(|&x| x == 0.0));
    }
}
