//! ct-contract: bit-exact
//!
//! Pure-Rust reference attention — all paper variants behind one
//! trait-based, batched, multi-head engine addressed by request
//! descriptors.
//!
//! Layout:
//!  - one file per kernel family ([`full`], [`clustered`], [`improved`],
//!    [`oracle`], [`lsh`], [`linear`]), each exporting its free
//!    functions (the historical API, still the substrate of the golden
//!    tests) plus an [`AttentionKernel`] implementation;
//!  - [`problem`] owns the request descriptors ([`AttnProblem`] /
//!    [`AttnBatch`]) every entry point takes — Q/K/V views plus the
//!    per-request options (the valid-length mask, the incremental
//!    `query_span`, the `causal` flag, and the KV-cache handles
//!    [`CacheRef`] / [`SessionRef`]) — so options travel through one
//!    struct instead of ever-growing argument lists;
//!  - [`backend`] owns the [`AttentionBackend`] execution seam (the
//!    native engine today; compiled-HLO and sharded backends plug in
//!    behind the same descriptor);
//!  - [`cache`] owns the incremental-decode subsystem: the per-session
//!    [`KvCache`] panel store and the [`CachingBackend`] that wraps
//!    any backend with cross-request KV caching;
//!  - [`sharded`] owns the multi-host fan-out: [`ShardedBackend`]
//!    splits a descriptor across shard workers (batch axis, then head
//!    axis) and reassembles the replies bit-identically, routing decode
//!    sessions to their owning shard by consistent hash;
//!  - this module owns the trait, the name-keyed [`REGISTRY`], the
//!    [`Variant`] config enum, and the batched entry points.
//!
//! Three roles (unchanged from the single-head era):
//!  1. second correctness oracle — integration tests compare these against
//!     HLO lowered from `python/compile/kernels/ref.py` on golden inputs;
//!  2. the fig. 4 scaling benchmark substrate (runs to N = 2^15 quickly,
//!     which interpret-mode Pallas cannot) — now including batched
//!     multi-head throughput over the exec pool;
//!  3. the analytic cost model (flops/bytes) used for the memory column
//!     of fig. 4 and the §Perf roofline estimates.
//!
//! **Batched determinism contract:** slice `s = b·H + h` of a
//! [`AttentionKernel::solve_batch`] call draws randomness only from
//! `prng::slice_stream(seed, s)`, so parallel execution over the exec
//! pool is bit-identical to the sequential per-slice loop
//! ([`solve_batch_seq`]) — verified by `proptest/attention_props.rs`.
//! Since the tiled-compute-core rewrite the contract extends *inside*
//! a slice: every kernel threads an [`ExecCtx`] through its GEMMs,
//! streaming softmax, clustering and top-k passes, all of which
//! partition **output rows** and never split a reduction, so
//! intra-slice parallelism is bit-invisible too (see `docs/PERF.md`).
//!
//! **Masking contract:** a problem with `valid_len = l` (or a batch
//! with per-sequence `lens`) solves exactly the unpadded `l`-row
//! problem — bit for bit — and zeroes the padded output rows.  The
//! mechanism is the valid-prefix view (padding always trails the valid
//! rows), so streaming softmax sweeps only valid key blocks, clustering
//! hashes and assigns only valid queries, and top-k can never select a
//! padded key.  See [`problem`] and `proptest/attention_props.rs`.
//!
//! **Span contract:** a problem with `query_span = s` emits output rows
//! `s..valid` bit-identical to the spanless solve and zeroes the rest —
//! the incremental-decode primitive.  Row-independent families (full,
//! shared-full, oracle-top) genuinely compute only the span; the
//! coupled families (clustered prunes to affected clusters; improved
//! and LSH recompute) emit the same bits either way.  See [`problem`]
//! and [`cache`].
//!
//! **Causal capability:** `causal = true` on a descriptor requests
//! autoregressive attention (row `i` attends keys `0..=i`).  Causality
//! is a per-kernel capability, not a universal contract:
//! [`AttentionKernel::supports_causal`] defaults to `false`, only the
//! [`linear`] family opts in, and the execution entry points reject
//! causal batches for non-supporting kernels up front.  For supporting
//! kernels the masking and span contracts hold verbatim under `causal`.

pub mod backend;
pub mod cache;
pub mod clustered;
pub mod full;
pub mod improved;
pub mod linear;
pub mod lsh;
pub mod oracle;
pub mod problem;
pub mod sharded;

pub use backend::{AttentionBackend, NativeBackend};
pub use cache::{CacheCounters, CacheQuant, CachingBackend, KvCache,
                KvCacheOptions, SeqOutcome};
pub use clustered::{centroids, clustered_attention,
                    clustered_attention_matrix,
                    clustered_span_attention_ctx, ClusteredAttention};
pub use full::{full_attention, full_attention_materialized,
               full_attention_matrix, streaming_softmax_attention,
               FullAttention, SharedFullAttention};
pub use improved::{improved_clustered_attention,
                   improved_clustered_attention_matrix,
                   ImprovedClusteredAttention};
pub use linear::{causal_linear_attention_span_ctx, linear_attention_ctx,
                 LinearAttention, RecurrentState};
pub use lsh::{reformer_attention, reformer_attention_ham_ctx,
              LshAttention, LshHamAttention};
pub use oracle::{oracle_top_attention, OracleTopAttention};
pub use problem::{AttnBatch, AttnProblem, CacheRef, SessionRef};
pub use sharded::{solve_batch_offset, InProcessShard, ShardCacheStats,
                  ShardEngine, ShardOptions, ShardReply, ShardRequest,
                  ShardSession, ShardTransport, ShardedBackend,
                  TcpShard};

use crate::exec::ExecCtx;
use crate::prng::{slice_stream, Xoshiro256};
use crate::tensor::batch::BatchMatrix;
use crate::tensor::Matrix;

/// Default hyper-parameters applied when a kernel is resolved by name.
pub const DEFAULT_BITS: usize = 63;
pub const DEFAULT_ITERS: usize = 10;
pub const DEFAULT_TOPK: usize = 32;
pub const DEFAULT_CHUNK: usize = 32;
/// Same-bucket candidates kept per query by the `lsh-ham` sign-bit
/// Hamming pre-filter (see [`lsh::LshHamAttention`]).
pub const DEFAULT_HAM_TOPK: usize = 16;

/// Which attention variant to run — mirrors `AttentionConfig` in L2.
#[derive(Debug, Clone, PartialEq)]
pub enum Variant {
    Full,
    SharedFull,
    Clustered { clusters: usize, bits: usize, iters: usize },
    ImprovedClustered { clusters: usize, bits: usize, iters: usize,
                        topk: usize },
    OracleTop { topk: usize },
    Lsh { rounds: usize, chunk: usize },
    /// LSH with the sign-bit Hamming candidate pre-filter
    /// (tolerance-gated: approximate relative to [`Variant::Lsh`]).
    LshHam { rounds: usize, chunk: usize, topk: usize },
    Linear,
}

impl Variant {
    pub fn name(&self) -> String {
        match self {
            Variant::Full => "full".into(),
            Variant::SharedFull => "shared-full".into(),
            Variant::Clustered { clusters, .. } => {
                format!("clustered-{clusters}")
            }
            Variant::ImprovedClustered { clusters, .. } => {
                format!("i-clustered-{clusters}")
            }
            Variant::OracleTop { topk } => format!("oracle-top-{topk}"),
            Variant::Lsh { rounds, .. } => format!("lsh-{rounds}"),
            Variant::LshHam { rounds, .. } => format!("lsh-ham-{rounds}"),
            Variant::Linear => "linear".into(),
        }
    }

    /// Inverse of [`Variant::name`]: resolve a paper-notation name via
    /// the registry, applying the `DEFAULT_*` hyper-parameters.
    pub fn parse(name: &str) -> Option<Variant> {
        REGISTRY.iter().find_map(|f| (f.parse)(name))
    }
}

/// Estimated cost of one attention call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cost {
    /// multiply-accumulate operations
    pub flops: u64,
    /// peak extra bytes beyond inputs/outputs (f32)
    pub bytes: u64,
}

/// One attention algorithm, usable single-slice or batched multi-head,
/// addressed by request descriptor.
///
/// [`solve`] computes one (sequence, head) slice described by an
/// [`AttnProblem`], parallelizing *within* the slice through the
/// [`ExecCtx`] (blocked GEMM stripes, streaming softmax rows, clustering
/// assignment — always partitioned over output rows, never across a
/// reduction, so any worker count produces the same bits).  A problem
/// with `valid_len` set obeys the masking contract: the valid rows are
/// bit-identical to solving the unpadded problem, the padded rows come
/// back zero.  [`solve_batch`] maps it over every slice of a
/// (B, H, N, D) workload, resolving per-sequence `lens` to valid-prefix
/// sub-problems and splitting the ctx budget between the slice axis and
/// the intra-slice ops (see [`ExecCtx::split_batch`]).
///
/// [`solve`]: AttentionKernel::solve
/// [`solve_batch`]: AttentionKernel::solve_batch
pub trait AttentionKernel: Send + Sync {
    /// Paper-notation name, e.g. `"i-clustered-100"`.
    fn name(&self) -> String;

    /// Solve one request slice: `p.q`,`p.k`: (N×Dk), `p.v`: (N×Dv)
    /// → (N×Dv), honoring `p.valid_len`.
    ///
    /// Output bits are independent of `ctx` (worker count and
    /// threshold) — the intra-slice determinism contract — and masked
    /// runs are bit-identical to unpadded runs (the masking contract).
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix;

    /// Closed-form cost of one slice (matches §3 complexity claims).
    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost;

    /// Does this kernel accept causal (`row i attends keys 0..=i`)
    /// problems?  Defaults to `false`; only the [`linear`] family opts
    /// in.  Non-supporting kernels assert on a causal descriptor, and
    /// the batched entry points reject causal batches up front.
    fn supports_causal(&self) -> bool {
        false
    }

    /// Batched multi-head forward over (batch × head) slices.
    ///
    /// Output slice `s` is a pure function of
    /// `(inputs[s], batch.seed, s)` — bit-identical for any ctx,
    /// including [`solve_batch_seq`].  Per-sequence `batch.lens` become
    /// valid-prefix sub-problems ([`BatchMatrix::slice_valid`]) before
    /// dispatch, so padded rows are never copied, hashed or swept, and
    /// the padded span of every output slice is zero.
    fn solve_batch(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx)
                   -> BatchMatrix {
        // public descriptor fields can bypass the constructors —
        // re-assert the invariants at the execution boundary
        batch.validate();
        assert!(!batch.causal || self.supports_causal(),
                "kernel {} does not support causal attention", self.name());
        let (q, k, v) = (batch.q, batch.k, batch.v);
        let mut out = BatchMatrix::zeros(q.batch, q.heads, q.rows, v.cols);
        if out.slices() == 0 || out.slice_len() == 0 {
            return out;
        }
        // split the budget: many slices → all workers on the slice
        // axis; few slices (one long request) → leftover workers move
        // inside each slice.  Placement never changes output bits.
        let (outer, inner) = ctx.split_batch(out.slices());
        let dv = v.cols;
        // workers write straight into disjoint output slices — no
        // per-slice result collection or second copy of the output
        let chunks = out.slices_mut();
        outer.for_each_mut(chunks, |s, chunk: &mut [f32]| {
            let mut rng = slice_stream(batch.seed, s as u64);
            let l = batch.slice_valid_len(s);
            let (qs, ks, vs) =
                (q.slice_valid(s, l), k.slice_valid(s, l),
                 v.slice_valid(s, l));
            let o = self.solve(&AttnProblem::new(&qs, &ks, &vs)
                                   .with_causal(batch.causal),
                               &mut rng, &inner);
            // rows l.. of the chunk stay zero — masked rows by contract
            chunk[..l * dv].copy_from_slice(&o.data);
        });
        out
    }
}

/// Explicit sequential single-slice loop — the reference schedule the
/// parallel [`AttentionKernel::solve_batch`] must match bit-for-bit,
/// ragged lens included.
pub fn solve_batch_seq(kernel: &dyn AttentionKernel, batch: &AttnBatch<'_>)
                       -> BatchMatrix {
    batch.validate();
    assert!(!batch.causal || kernel.supports_causal(),
            "kernel {} does not support causal attention", kernel.name());
    let (q, k, v) = (batch.q, batch.k, batch.v);
    let mut out = BatchMatrix::zeros(q.batch, q.heads, q.rows, v.cols);
    if out.slices() == 0 || out.slice_len() == 0 {
        return out;
    }
    let ctx = ExecCtx::sequential();
    let dv = v.cols;
    for s in 0..q.slices() {
        let mut rng = slice_stream(batch.seed, s as u64);
        let l = batch.slice_valid_len(s);
        let (qs, ks, vs) =
            (q.slice_valid(s, l), k.slice_valid(s, l), v.slice_valid(s, l));
        let o = kernel.solve(&AttnProblem::new(&qs, &ks, &vs)
                                 .with_causal(batch.causal),
                             &mut rng, &ctx);
        out.slice_mut(s)[..l * dv].copy_from_slice(&o.data);
    }
    out
}

// ---------------------------------------------------------------------------
// name-keyed registry
// ---------------------------------------------------------------------------

/// One kernel family in the registry: its key and its name parser.
pub struct KernelFamily {
    /// Family key (the name prefix, exact for parameterless families).
    pub key: &'static str,
    /// Parse a full kernel name (e.g. `"clustered-100"`) into a config.
    pub parse: fn(&str) -> Option<Variant>,
}

fn parse_full(name: &str) -> Option<Variant> {
    (name == "full").then_some(Variant::Full)
}

fn parse_shared_full(name: &str) -> Option<Variant> {
    (name == "shared-full").then_some(Variant::SharedFull)
}

fn parse_clustered(name: &str) -> Option<Variant> {
    let clusters: usize = name.strip_prefix("clustered-")?.parse().ok()?;
    Some(Variant::Clustered { clusters, bits: DEFAULT_BITS,
                              iters: DEFAULT_ITERS })
}

fn parse_improved(name: &str) -> Option<Variant> {
    let clusters: usize = name.strip_prefix("i-clustered-")?.parse().ok()?;
    Some(Variant::ImprovedClustered { clusters, bits: DEFAULT_BITS,
                                      iters: DEFAULT_ITERS,
                                      topk: DEFAULT_TOPK })
}

fn parse_oracle(name: &str) -> Option<Variant> {
    let topk: usize = name.strip_prefix("oracle-top-")?.parse().ok()?;
    Some(Variant::OracleTop { topk })
}

fn parse_lsh(name: &str) -> Option<Variant> {
    let rounds: usize = name.strip_prefix("lsh-")?.parse().ok()?;
    Some(Variant::Lsh { rounds, chunk: DEFAULT_CHUNK })
}

fn parse_lsh_ham(name: &str) -> Option<Variant> {
    let rounds: usize = name.strip_prefix("lsh-ham-")?.parse().ok()?;
    Some(Variant::LshHam { rounds, chunk: DEFAULT_CHUNK,
                           topk: DEFAULT_HAM_TOPK })
}

fn parse_linear(name: &str) -> Option<Variant> {
    (name == "linear").then_some(Variant::Linear)
}

/// Every kernel family, keyed by paper-notation name.
pub static REGISTRY: &[KernelFamily] = &[
    KernelFamily { key: "i-clustered", parse: parse_improved },
    KernelFamily { key: "clustered", parse: parse_clustered },
    KernelFamily { key: "oracle-top", parse: parse_oracle },
    KernelFamily { key: "lsh-ham", parse: parse_lsh_ham },
    KernelFamily { key: "lsh", parse: parse_lsh },
    KernelFamily { key: "linear", parse: parse_linear },
    KernelFamily { key: "shared-full", parse: parse_shared_full },
    KernelFamily { key: "full", parse: parse_full },
];

/// Registry family keys, registry order.
pub fn kernel_families() -> Vec<&'static str> {
    REGISTRY.iter().map(|f| f.key).collect()
}

/// Instantiate the kernel for a variant config.
pub fn kernel_for(variant: &Variant) -> Box<dyn AttentionKernel> {
    match variant {
        Variant::Full => Box::new(FullAttention),
        Variant::SharedFull => Box::new(SharedFullAttention),
        Variant::Clustered { clusters, bits, iters } => {
            Box::new(ClusteredAttention { clusters: *clusters, bits: *bits,
                                          iters: *iters })
        }
        Variant::ImprovedClustered { clusters, bits, iters, topk } => {
            Box::new(ImprovedClusteredAttention {
                clusters: *clusters, bits: *bits, iters: *iters,
                topk: *topk })
        }
        Variant::OracleTop { topk } => {
            Box::new(OracleTopAttention { topk: *topk })
        }
        Variant::Lsh { rounds, chunk } => {
            Box::new(LshAttention { rounds: *rounds, chunk: *chunk })
        }
        Variant::LshHam { rounds, chunk, topk } => {
            Box::new(LshHamAttention { rounds: *rounds, chunk: *chunk,
                                       topk: *topk })
        }
        Variant::Linear => Box::new(LinearAttention),
    }
}

/// Resolve a kernel by paper-notation name (`DEFAULT_*` hyper-params).
pub fn kernel_by_name(name: &str) -> Option<Box<dyn AttentionKernel>> {
    Variant::parse(name).map(|v| kernel_for(&v))
}

// ---------------------------------------------------------------------------
// variant-dispatch entry points
// ---------------------------------------------------------------------------

/// Dispatch a variant on one request descriptor.
pub fn solve(variant: &Variant, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
    kernel_for(variant).solve(p, rng, ctx)
}

/// Batched dispatch of a variant over a (B, H, N, D) descriptor.
pub fn solve_batch(variant: &Variant, batch: &AttnBatch<'_>, ctx: &ExecCtx)
                   -> BatchMatrix {
    kernel_for(variant).solve_batch(batch, ctx)
}

/// Closed-form cost of each variant (matches §3 complexity claims).
pub fn cost_model(variant: &Variant, n: usize, dk: usize, dv: usize)
                  -> Cost {
    kernel_for(variant).cost(n, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::{self, Clustering};

    fn qkv(n: usize, dk: usize, dv: usize, seed: u64)
           -> (Matrix, Matrix, Matrix, Xoshiro256) {
        let mut rng = Xoshiro256::new(seed);
        let q = Matrix::randn(n, dk, &mut rng);
        let k = Matrix::randn(n, dk, &mut rng);
        let v = Matrix::randn(n, dv, &mut rng);
        (q, k, v, rng)
    }

    #[test]
    fn full_attention_rows_are_convex_combinations() {
        let (q, k, v, _) = qkv(24, 8, 8, 1);
        let a = full_attention_matrix(&q, &k);
        for r in 0..24 {
            let s: f32 = a.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        let out = full_attention(&q, &k, &v);
        assert_eq!((out.rows, out.cols), (24, 8));
    }

    #[test]
    fn clustered_with_singleton_clusters_is_exact() {
        let (q, k, v, _) = qkv(16, 8, 8, 2);
        let cl = Clustering {
            n_clusters: 16,
            groups: (0..16u32).collect(),
            counts: vec![1; 16],
            cost: 0,
        };
        let got = clustered_attention(&q, &k, &v, &cl);
        let want = full_attention(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn improved_is_never_worse_than_clustered_prop2() {
        let (q, k, _, mut rng) = qkv(48, 16, 16, 3);
        let cl = clustering::cluster_queries(&q, 6, 31, 5, &mut rng);
        let a = full_attention_matrix(&q, &k);
        let a_c = clustered_attention_matrix(&q, &k, &cl);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        for i in 0..48 {
            let j = cl.groups[i] as usize;
            let ec: f32 = (0..48)
                .map(|l| (a_c.at(j, l) - a.at(i, l)).abs())
                .sum();
            let et: f32 = (0..48)
                .map(|l| (a_t.at(i, l) - a.at(i, l)).abs())
                .sum();
            assert!(et <= ec + 1e-4, "row {i}: {et} > {ec}");
        }
    }

    #[test]
    fn improved_matrix_rows_are_distributions() {
        let (q, k, _, mut rng) = qkv(32, 8, 8, 4);
        let cl = clustering::cluster_queries(&q, 4, 31, 5, &mut rng);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        for i in 0..32 {
            let s: f32 = a_t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
            assert!(a_t.row(i).iter().all(|&w| w >= -1e-6));
        }
    }

    #[test]
    fn improved_attention_output_matches_matrix_times_v() {
        let (q, k, v, mut rng) = qkv(32, 8, 8, 5);
        let cl = clustering::cluster_queries(&q, 4, 31, 5, &mut rng);
        let fast = improved_clustered_attention(&q, &k, &v, &cl, 8);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        let dense = a_t.matmul(&v);
        assert!(fast.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn oracle_top_with_full_k_is_exact() {
        let (q, k, v, _) = qkv(20, 8, 8, 6);
        let got = oracle_top_attention(&q, &k, &v, 20);
        let want = full_attention(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn reformer_output_is_finite_and_right_shape() {
        let (q, _, v, mut rng) = qkv(64, 16, 16, 7);
        let out = reformer_attention(&q, &v, 2, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (64, 16));
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cost_model_full_is_quadratic_clustered_linear() {
        let full_1k = cost_model(&Variant::Full, 1024, 64, 64);
        let full_2k = cost_model(&Variant::Full, 2048, 64, 64);
        assert_eq!(full_2k.flops, full_1k.flops * 4);
        let cl = Variant::Clustered { clusters: 100, bits: 63, iters: 10 };
        let cl_1k = cost_model(&cl, 1024, 64, 64);
        let cl_2k = cost_model(&cl, 2048, 64, 64);
        assert_eq!(cl_2k.flops, cl_1k.flops * 2);
    }

    #[test]
    fn variant_names_match_paper_notation() {
        assert_eq!(Variant::Full.name(), "full");
        assert_eq!(
            Variant::Clustered { clusters: 100, bits: 63, iters: 10 }.name(),
            "clustered-100"
        );
        assert_eq!(Variant::Lsh { rounds: 4, chunk: 32 }.name(), "lsh-4");
        assert_eq!(Variant::LshHam { rounds: 4, chunk: 32, topk: 16 }
                       .name(),
                   "lsh-ham-4");
    }

    // --- trait / registry / batch ------------------------------------

    fn test_variants() -> Vec<Variant> {
        vec![
            Variant::Full,
            Variant::SharedFull,
            Variant::Clustered { clusters: 4, bits: 31, iters: 5 },
            Variant::ImprovedClustered { clusters: 4, bits: 31, iters: 5,
                                         topk: 8 },
            Variant::OracleTop { topk: 8 },
            Variant::Lsh { rounds: 2, chunk: 16 },
            Variant::LshHam { rounds: 2, chunk: 16, topk: 8 },
            Variant::Linear,
        ]
    }

    #[test]
    fn registry_resolves_every_paper_name() {
        for name in ["full", "shared-full", "clustered-100",
                     "i-clustered-100", "oracle-top-32", "lsh-4",
                     "lsh-ham-4", "linear"] {
            let kernel = kernel_by_name(name)
                .unwrap_or_else(|| panic!("registry missed {name}"));
            assert_eq!(kernel.name(), name);
            assert_eq!(Variant::parse(name).unwrap().name(), name);
        }
        for bad in ["", "fullx", "clustered-", "i-clustered-x",
                    "oracle-top--3", "lshx-1", "lsh-ham-", "linear-4"] {
            assert!(kernel_by_name(bad).is_none(), "{bad:?} resolved");
        }
        assert_eq!(kernel_families().len(), REGISTRY.len());
    }

    #[test]
    fn kernel_solve_matches_variant_dispatch() {
        let (q, k, v, _) = qkv(32, 8, 8, 11);
        let ctx = ExecCtx::sequential();
        for var in test_variants() {
            let mut r1 = Xoshiro256::new(5);
            let mut r2 = Xoshiro256::new(5);
            let p = AttnProblem::new(&q, &k, &v);
            let a = solve(&var, &p, &mut r1, &ctx);
            let b = kernel_for(&var).solve(&p, &mut r2, &ctx);
            assert_eq!(a.data, b.data, "{}", var.name());
        }
    }

    #[test]
    fn solve_batch_parallel_is_bit_identical_to_sequential() {
        use crate::exec::WorkerPool;
        let mut rng = Xoshiro256::new(21);
        let (b, h, n, d) = (2, 2, 64, 16);
        let q = BatchMatrix::randn(b, h, n, d, &mut rng);
        let k = BatchMatrix::randn(b, h, n, d, &mut rng);
        let v = BatchMatrix::randn(b, h, n, d, &mut rng);
        let ctx = ExecCtx::new(WorkerPool::new(4));
        let batch = AttnBatch::new(&q, &k, &v, 7);
        for var in test_variants() {
            let kernel = kernel_for(&var);
            let par = kernel.solve_batch(&batch, &ctx);
            let seq = solve_batch_seq(kernel.as_ref(), &batch);
            assert!(par.bit_identical(&seq), "{} diverged", var.name());
            assert_eq!((par.batch, par.heads, par.rows, par.cols),
                       (b, h, n, d));
        }
    }

    #[test]
    fn intra_slice_parallelism_never_changes_the_bits() {
        use crate::exec::WorkerPool;
        let (q, k, v, _) = qkv(96, 16, 16, 23);
        let p = AttnProblem::new(&q, &k, &v);
        for var in test_variants() {
            let kernel = kernel_for(&var);
            let mut r_seq = Xoshiro256::new(11);
            let want = kernel.solve(&p, &mut r_seq, &ExecCtx::sequential());
            for workers in [2, 5] {
                // par_rows = 1 forces every row-partitioned op parallel
                let ctx =
                    ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
                let mut r_par = Xoshiro256::new(11);
                let got = kernel.solve(&p, &mut r_par, &ctx);
                assert!(got.bit_identical(&want),
                        "{} diverged at workers={workers}", var.name());
            }
        }
    }

    #[test]
    fn solve_batch_slices_match_single_slice_runs() {
        use crate::exec::WorkerPool;
        let mut rng = Xoshiro256::new(22);
        let (b, h, n, d) = (2, 3, 32, 8);
        let q = BatchMatrix::randn(b, h, n, d, &mut rng);
        let k = BatchMatrix::randn(b, h, n, d, &mut rng);
        let v = BatchMatrix::randn(b, h, n, d, &mut rng);
        let var = Variant::Clustered { clusters: 4, bits: 31, iters: 5 };
        let out = solve_batch(&var, &AttnBatch::new(&q, &k, &v, 3),
                              &ExecCtx::new(WorkerPool::new(3)));
        let kernel = kernel_for(&var);
        for s in 0..q.slices() {
            let mut rng_s = crate::prng::slice_stream(3, s as u64);
            let (qs, ks, vs) = (q.slice_matrix(s), k.slice_matrix(s),
                                v.slice_matrix(s));
            let want = kernel.solve(&AttnProblem::new(&qs, &ks, &vs),
                                    &mut rng_s, &ExecCtx::sequential());
            assert_eq!(out.slice_matrix(s).data, want.data, "slice {s}");
        }
    }

    #[test]
    fn masked_solve_equals_unpadded_solve_with_zero_tail() {
        // the masking contract on every family at one shape; the
        // proptests sweep shapes, lens and worker counts
        let (q, k, v, _) = qkv(48, 8, 8, 31);
        let l = 29; // ragged: not a multiple of any tile or chunk size
        let (qu, ku, vu) = (q.row_prefix(l), k.row_prefix(l),
                            v.row_prefix(l));
        let ctx = ExecCtx::sequential();
        for var in test_variants() {
            let kernel = kernel_for(&var);
            let mut r_pad = Xoshiro256::new(3);
            let masked = kernel.solve(
                &AttnProblem::new(&q, &k, &v).with_valid_len(l),
                &mut r_pad, &ctx);
            let mut r_ref = Xoshiro256::new(3);
            let want = kernel.solve(&AttnProblem::new(&qu, &ku, &vu),
                                    &mut r_ref, &ctx);
            assert_eq!((masked.rows, masked.cols), (48, 8), "{}",
                       var.name());
            assert!(masked.row_prefix(l).bit_identical(&want),
                    "{} masked valid rows diverged from unpadded",
                    var.name());
            assert!(masked.data[l * 8..].iter().all(|&x| x == 0.0),
                    "{} left non-zero padded rows", var.name());
        }
    }

    #[test]
    fn solve_batch_with_lens_masks_per_sequence() {
        use crate::exec::WorkerPool;
        let mut rng = Xoshiro256::new(40);
        let (b, h, n, d) = (3, 2, 32, 8);
        let q = BatchMatrix::randn(b, h, n, d, &mut rng);
        let k = BatchMatrix::randn(b, h, n, d, &mut rng);
        let v = BatchMatrix::randn(b, h, n, d, &mut rng);
        let lens = [5usize, 32, 17];
        let kernel =
            kernel_for(&Variant::ImprovedClustered { clusters: 4, bits: 31,
                                                     iters: 5, topk: 8 });
        let batch = AttnBatch::new(&q, &k, &v, 9).with_lens(&lens);
        let out = kernel.solve_batch(
            &batch, &ExecCtx::with_par_rows(WorkerPool::new(4), 1));
        for s in 0..q.slices() {
            let l = lens[s / h];
            let mut rng_s = crate::prng::slice_stream(9, s as u64);
            let (qs, ks, vs) = (q.slice_valid(s, l), k.slice_valid(s, l),
                                v.slice_valid(s, l));
            let want = kernel.solve(&AttnProblem::new(&qs, &ks, &vs),
                                    &mut rng_s, &ExecCtx::sequential());
            let got = out.slice_matrix(s);
            assert_eq!(&got.data[..l * d], &want.data[..], "slice {s}");
            assert!(got.data[l * d..].iter().all(|&x| x == 0.0),
                    "slice {s} padded rows not zero");
        }
        // and the parallel schedule matches the sequential reference
        assert!(out.bit_identical(&solve_batch_seq(kernel.as_ref(),
                                                   &batch)));
    }

    #[test]
    #[should_panic(expected = "lens")]
    fn solve_batch_validates_literally_constructed_descriptors() {
        // public fields can bypass AttnBatch::new/with_lens — the
        // execution boundary must still catch the malformed descriptor
        let mut rng = Xoshiro256::new(60);
        let q = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let k = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let v = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let lens = [5usize]; // one entry for a 2-sequence batch
        let bad = AttnBatch { q: &q, k: &k, v: &v, seed: 0,
                              lens: Some(&lens), sessions: None,
                              causal: false };
        let _ = kernel_for(&Variant::Full)
            .solve_batch(&bad, &ExecCtx::sequential());
    }

    #[test]
    #[should_panic(expected = "valid_len")]
    fn kernels_validate_literally_constructed_problems() {
        let (q, k, v, _) = qkv(8, 4, 4, 61);
        let bad = AttnProblem { q: &q, k: &k, v: &v, valid_len: Some(99),
                                query_span: None, causal: false };
        let mut rng = Xoshiro256::new(0);
        let _ = kernel_for(&Variant::Full).solve(&bad, &mut rng,
                                                 &ExecCtx::sequential());
    }

    #[test]
    fn only_the_linear_family_accepts_causal_batches() {
        for var in test_variants() {
            let kernel = kernel_for(&var);
            assert_eq!(kernel.supports_causal(), var == Variant::Linear,
                       "{}", var.name());
        }
    }

    #[test]
    #[should_panic(expected = "causal")]
    fn causal_batches_are_rejected_for_non_supporting_kernels() {
        let mut rng = Xoshiro256::new(62);
        let q = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let k = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let v = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let batch = AttnBatch::new(&q, &k, &v, 0).with_causal(true);
        let _ = kernel_for(&Variant::Full)
            .solve_batch(&batch, &ExecCtx::sequential());
    }

    #[test]
    fn causal_linear_batch_matches_the_sequential_loop() {
        use crate::exec::WorkerPool;
        let mut rng = Xoshiro256::new(63);
        let (b, h, n, d) = (2, 2, 48, 8);
        let q = BatchMatrix::randn(b, h, n, d, &mut rng);
        let k = BatchMatrix::randn(b, h, n, d, &mut rng);
        let v = BatchMatrix::randn(b, h, n, d, &mut rng);
        let lens = [31usize, 48];
        let batch = AttnBatch::new(&q, &k, &v, 5)
            .with_lens(&lens)
            .with_causal(true);
        let kernel = kernel_for(&Variant::Linear);
        let par = kernel.solve_batch(
            &batch, &ExecCtx::with_par_rows(WorkerPool::new(4), 1));
        let seq = solve_batch_seq(kernel.as_ref(), &batch);
        assert!(par.bit_identical(&seq));
        // causal actually changes the math vs the bidirectional solve
        let bi = kernel.solve_batch(
            &AttnBatch::new(&q, &k, &v, 5).with_lens(&lens),
            &ExecCtx::sequential());
        assert!(!par.bit_identical(&bi));
    }

    #[test]
    fn spanned_solve_equals_the_span_rows_of_the_spanless_solve() {
        // the span contract on every family at one shape; the proptests
        // sweep shapes, spans and worker counts
        let (q, k, v, _) = qkv(48, 8, 8, 70);
        let (l, s) = (41, 29); // ragged valid length, interior span
        let ctx = ExecCtx::sequential();
        for var in test_variants() {
            let kernel = kernel_for(&var);
            let mut r_span = Xoshiro256::new(4);
            let spanned = kernel.solve(
                &AttnProblem::new(&q, &k, &v)
                    .with_valid_len(l)
                    .with_query_span(s),
                &mut r_span, &ctx);
            let mut r_ref = Xoshiro256::new(4);
            let want = kernel.solve(
                &AttnProblem::new(&q, &k, &v).with_valid_len(l),
                &mut r_ref, &ctx);
            assert_eq!((spanned.rows, spanned.cols), (48, 8), "{}",
                       var.name());
            assert!(spanned
                        .row_span(s, l)
                        .bit_identical(&want.row_span(s, l)),
                    "{} span rows diverged from the spanless solve",
                    var.name());
            assert!(spanned.data[..s * 8].iter().all(|&x| x == 0.0),
                    "{} left non-zero pre-span rows", var.name());
            assert!(spanned.data[l * 8..].iter().all(|&x| x == 0.0),
                    "{} left non-zero padded rows", var.name());
        }
    }
}
