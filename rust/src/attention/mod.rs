//! Pure-Rust reference attention (all paper variants, single head).
//!
//! Three roles:
//!  1. second correctness oracle — integration tests compare these against
//!     HLO lowered from `python/compile/kernels/ref.py` on golden inputs;
//!  2. the fig. 4 scaling benchmark substrate (runs to N = 2^15 quickly,
//!     which interpret-mode Pallas cannot);
//!  3. the analytic cost model (flops/bytes) used for the memory column
//!     of fig. 4 and the §Perf roofline estimates.

use crate::clustering::{self, Clustering};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, softmax_inplace, topk_indices, Matrix};

/// Which attention variant to run — mirrors `AttentionConfig` in L2.
#[derive(Debug, Clone, PartialEq)]
pub enum Variant {
    Full,
    SharedFull,
    Clustered { clusters: usize, bits: usize, iters: usize },
    ImprovedClustered { clusters: usize, bits: usize, iters: usize,
                        topk: usize },
    OracleTop { topk: usize },
    Lsh { rounds: usize, chunk: usize },
}

impl Variant {
    pub fn name(&self) -> String {
        match self {
            Variant::Full => "full".into(),
            Variant::SharedFull => "shared-full".into(),
            Variant::Clustered { clusters, .. } => {
                format!("clustered-{clusters}")
            }
            Variant::ImprovedClustered { clusters, .. } => {
                format!("i-clustered-{clusters}")
            }
            Variant::OracleTop { topk } => format!("oracle-top-{topk}"),
            Variant::Lsh { rounds, .. } => format!("lsh-{rounds}"),
        }
    }
}

/// Dispatch a variant.  `q`,`k`: (N×Dk), `v`: (N×Dv) → (N×Dv).
pub fn run(variant: &Variant, q: &Matrix, k: &Matrix, v: &Matrix,
           rng: &mut Xoshiro256) -> Matrix {
    match variant {
        Variant::Full => full_attention(q, k, v),
        Variant::SharedFull => full_attention(q, q, v),
        Variant::Clustered { clusters, bits, iters } => {
            let cl = clustering::cluster_queries(q, *clusters, *bits,
                                                 *iters, rng);
            clustered_attention(q, k, v, &cl)
        }
        Variant::ImprovedClustered { clusters, bits, iters, topk } => {
            let cl = clustering::cluster_queries(q, *clusters, *bits,
                                                 *iters, rng);
            improved_clustered_attention(q, k, v, &cl, *topk)
        }
        Variant::OracleTop { topk } => oracle_top_attention(q, k, v, *topk),
        Variant::Lsh { rounds, chunk } => {
            reformer_attention(q, v, *rounds, *chunk, rng)
        }
    }
}

// ---------------------------------------------------------------------------
// full attention (eq. 1–2)
// ---------------------------------------------------------------------------

pub fn full_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k); // (N, N)
    logits.scale(scale);
    logits.softmax_rows();
    logits.matmul(v)
}

/// Dense attention matrix (fig. 8 dumps).
pub fn full_attention_matrix(q: &Matrix, k: &Matrix) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k);
    logits.scale(scale);
    logits.softmax_rows();
    logits
}

// ---------------------------------------------------------------------------
// clustered attention (eqs. 3–6)
// ---------------------------------------------------------------------------

/// Eq. (3): centroids of the member queries.
pub fn centroids(q: &Matrix, cl: &Clustering) -> Matrix {
    let mut cent = Matrix::zeros(cl.n_clusters, q.cols);
    for i in 0..q.rows {
        axpy(cent.row_mut(cl.groups[i] as usize), 1.0, q.row(i));
    }
    for c in 0..cl.n_clusters {
        if cl.counts[c] > 0 {
            let inv = 1.0 / cl.counts[c] as f32;
            for val in cent.row_mut(c) {
                *val *= inv;
            }
        }
    }
    cent
}

/// Eq. (4): A^c = softmax(Q^c K^T / sqrt(Dk)) — (C × N).
pub fn clustered_attention_matrix(q: &Matrix, k: &Matrix, cl: &Clustering)
                                  -> Matrix {
    let cent = centroids(q, cl);
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut a_c = cent.matmul_nt(k);
    a_c.scale(scale);
    a_c.softmax_rows();
    a_c
}

/// Eqs. (4)–(6): O(N·C·D).
pub fn clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                           cl: &Clustering) -> Matrix {
    let a_c = clustered_attention_matrix(q, k, cl);
    let v_c = a_c.matmul(v); // (C, Dv)
    let mut out = Matrix::zeros(q.rows, v.cols);
    for i in 0..q.rows {
        out.row_mut(i).copy_from_slice(v_c.row(cl.groups[i] as usize));
    }
    out
}

// ---------------------------------------------------------------------------
// improved clustered attention (eqs. 9–11 / suppl. 15–17)
// ---------------------------------------------------------------------------

pub fn improved_clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                                    cl: &Clustering, topk: usize) -> Matrix {
    let n = q.rows;
    let c = cl.n_clusters;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix(q, k, cl); // (C, N)

    // per-cluster top-k keys, captured mass m̂ (eq. 9) and V̂^b basis
    let mut top: Vec<Vec<usize>> = Vec::with_capacity(c);
    let mut mhat = vec![0f32; c];
    let mut v_b = Matrix::zeros(c, v.cols); // complement average per cluster
    for j in 0..c {
        let idx = topk_indices(a_c.row(j), topk);
        mhat[j] = idx.iter().map(|&i| a_c.at(j, i)).sum();
        // V̂^b row: clustered attention with top-k columns zeroed (eq. 17)
        let row = a_c.row(j);
        let mut acc = vec![0f32; v.cols];
        for (key_idx, &w) in row.iter().enumerate() {
            if w != 0.0 && !idx.contains(&key_idx) {
                axpy(&mut acc, w, v.row(key_idx));
            }
        }
        v_b.row_mut(j).copy_from_slice(&acc);
        top.push(idx);
    }

    // V̂ = V̂^t + V̂^b (eqs. 15–16)
    let mut out = Matrix::zeros(n, v.cols);
    let mut dots = vec![0f32; topk];
    for i in 0..n {
        let j = cl.groups[i] as usize;
        let idx = &top[j];
        let t = idx.len();
        for (slot, &key_idx) in idx.iter().enumerate() {
            dots[slot] = dot(q.row(i), k.row(key_idx)) * scale;
        }
        softmax_inplace(&mut dots[..t]);
        let orow = out.row_mut(i);
        orow.copy_from_slice(v_b.row(j));
        for (slot, &key_idx) in idx.iter().enumerate() {
            axpy(orow, dots[slot] * mhat[j], v.row(key_idx));
        }
    }
    out
}

/// Dense A^t (eq. 10) for fig. 8.
pub fn improved_clustered_attention_matrix(q: &Matrix, k: &Matrix,
                                           cl: &Clustering, topk: usize)
                                           -> Matrix {
    let n = q.rows;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix(q, k, cl);
    let mut out = Matrix::zeros(n, n);
    let mut dots = vec![0f32; topk];
    for i in 0..n {
        let j = cl.groups[i] as usize;
        let idx = topk_indices(a_c.row(j), topk);
        let mhat: f32 = idx.iter().map(|&l| a_c.at(j, l)).sum();
        out.row_mut(i).copy_from_slice(a_c.row(j));
        for (slot, &l) in idx.iter().enumerate() {
            dots[slot] = dot(q.row(i), k.row(l)) * scale;
        }
        softmax_inplace(&mut dots[..idx.len()]);
        for (slot, &l) in idx.iter().enumerate() {
            out.set(i, l, dots[slot] * mhat);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// oracle-top baseline (§4.1)
// ---------------------------------------------------------------------------

pub fn oracle_top_attention(q: &Matrix, k: &Matrix, v: &Matrix, topk: usize)
                            -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut out = Matrix::zeros(q.rows, v.cols);
    let mut logits = vec![0f32; k.rows];
    for i in 0..q.rows {
        for j in 0..k.rows {
            logits[j] = dot(q.row(i), k.row(j)) * scale;
        }
        let idx = topk_indices(&logits, topk);
        let mut w: Vec<f32> = idx.iter().map(|&j| logits[j]).collect();
        softmax_inplace(&mut w);
        let orow = out.row_mut(i);
        for (slot, &j) in idx.iter().enumerate() {
            axpy(orow, w[slot], v.row(j));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Reformer-style LSH attention baseline
// ---------------------------------------------------------------------------

/// Shared-QK chunked LSH attention; rounds combined with logsumexp weights.
pub fn reformer_attention(x: &Matrix, v: &Matrix, rounds: usize,
                          chunk: usize, rng: &mut Xoshiro256) -> Matrix {
    let n = x.rows;
    assert_eq!(n % chunk, 0, "N must be divisible by chunk");
    let n_buckets = 16usize;
    let scale = 1.0 / (x.cols as f32).sqrt();

    let mut outs: Vec<Matrix> = Vec::with_capacity(rounds);
    let mut lses: Vec<Vec<f32>> = Vec::with_capacity(rounds);

    for _ in 0..rounds {
        // angular LSH: argmax over [xR; -xR]
        let rot = Matrix::randn(n_buckets / 2, x.cols, rng);
        let mut buckets = vec![0usize; n];
        for i in 0..n {
            let (mut best_v, mut best_b) = (f32::NEG_INFINITY, 0usize);
            for b in 0..n_buckets / 2 {
                let h = dot(x.row(i), rot.row(b));
                if h > best_v {
                    best_v = h;
                    best_b = b;
                }
                if -h > best_v {
                    best_v = -h;
                    best_b = b + n_buckets / 2;
                }
            }
            buckets[i] = best_b;
        }
        // stable sort by bucket
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (buckets[i], i));

        let mut out = Matrix::zeros(n, v.cols);
        let mut lse = vec![f32::NEG_INFINITY; n];
        let n_chunks = n / chunk;
        for cidx in 0..n_chunks {
            let prev = (cidx + n_chunks - 1) % n_chunks;
            // candidate keys: previous chunk ++ own chunk
            let cand: Vec<usize> = order[prev * chunk..(prev + 1) * chunk]
                .iter()
                .chain(&order[cidx * chunk..(cidx + 1) * chunk])
                .copied()
                .collect();
            for &qi in &order[cidx * chunk..(cidx + 1) * chunk] {
                let mut logits = Vec::with_capacity(cand.len());
                for &kj in &cand {
                    let l = if buckets[kj] != buckets[qi] {
                        f32::NEG_INFINITY
                    } else if kj == qi {
                        -5e8 // self only as a fallback
                    } else {
                        dot(x.row(qi), x.row(kj)) * scale
                    };
                    logits.push(l);
                }
                let m = logits.iter().copied().fold(f32::NEG_INFINITY,
                                                    f32::max);
                let mut sum = 0f32;
                for l in &mut logits {
                    *l = (*l - m).exp();
                    sum += *l;
                }
                lse[qi] = m + sum.max(1e-30).ln();
                let inv = 1.0 / sum.max(1e-30);
                let orow = out.row_mut(qi);
                for (slot, &kj) in cand.iter().enumerate() {
                    if logits[slot] > 0.0 {
                        axpy(orow, logits[slot] * inv, v.row(kj));
                    }
                }
            }
        }
        outs.push(out);
        lses.push(lse);
    }

    // combine rounds: softmax over per-position lse
    let mut combined = Matrix::zeros(n, v.cols);
    for i in 0..n {
        let m = (0..rounds)
            .map(|r| lses[r][i])
            .fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f32> = (0..rounds).map(|r| (lses[r][i] - m).exp())
            .collect();
        let tot: f32 = ws.iter().sum();
        let orow = combined.row_mut(i);
        for r in 0..rounds {
            axpy(orow, ws[r] / tot.max(1e-30), outs[r].row(i));
        }
    }
    combined
}

// ---------------------------------------------------------------------------
// analytic cost model (fig. 4 memory column + §Perf rooflines)
// ---------------------------------------------------------------------------

/// Estimated cost of one attention call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cost {
    /// multiply-accumulate operations
    pub flops: u64,
    /// peak extra bytes beyond inputs/outputs (f32)
    pub bytes: u64,
}

/// Closed-form cost of each variant (matches §3 complexity claims).
pub fn cost_model(variant: &Variant, n: usize, dk: usize, dv: usize)
                  -> Cost {
    let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
    match variant {
        Variant::Full | Variant::SharedFull => Cost {
            flops: n64 * n64 * (dk64 + dv64),
            bytes: 4 * n64 * n64,
        },
        Variant::Clustered { clusters, bits, iters } => {
            let (c, b, l) = (*clusters as u64, *bits as u64, *iters as u64);
            Cost {
                // LSH + Lloyd (O(NCL + ND_kB)) + centroid attention
                flops: n64 * dk64 * b + n64 * c * l
                    + c * n64 * (dk64 + dv64),
                bytes: 4 * c * n64 + n64 * b / 8,
            }
        }
        Variant::ImprovedClustered { clusters, bits, iters, topk } => {
            let base = cost_model(
                &Variant::Clustered { clusters: *clusters, bits: *bits,
                                      iters: *iters }, n, dk, dv);
            Cost {
                flops: base.flops
                    + n64 * (*topk as u64) * (dk64 + dv64),
                bytes: base.bytes + 4 * n64 * (*topk as u64),
            }
        }
        Variant::OracleTop { topk } => Cost {
            flops: n64 * n64 * dk64 + n64 * (*topk as u64) * dv64,
            bytes: 4 * n64 * n64,
        },
        Variant::Lsh { rounds, chunk } => {
            let (r, c) = (*rounds as u64, *chunk as u64);
            Cost {
                flops: r * n64 * 2 * c * (dk64 + dv64)
                    + r * n64 * dk64 * 8,
                bytes: 4 * r * n64 * 2 * c,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qkv(n: usize, dk: usize, dv: usize, seed: u64)
           -> (Matrix, Matrix, Matrix, Xoshiro256) {
        let mut rng = Xoshiro256::new(seed);
        let q = Matrix::randn(n, dk, &mut rng);
        let k = Matrix::randn(n, dk, &mut rng);
        let v = Matrix::randn(n, dv, &mut rng);
        (q, k, v, rng)
    }

    #[test]
    fn full_attention_rows_are_convex_combinations() {
        let (q, k, v, _) = qkv(24, 8, 8, 1);
        let a = full_attention_matrix(&q, &k);
        for r in 0..24 {
            let s: f32 = a.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        let out = full_attention(&q, &k, &v);
        assert_eq!((out.rows, out.cols), (24, 8));
    }

    #[test]
    fn clustered_with_singleton_clusters_is_exact() {
        let (q, k, v, _) = qkv(16, 8, 8, 2);
        let cl = Clustering {
            n_clusters: 16,
            groups: (0..16u32).collect(),
            counts: vec![1; 16],
            cost: 0,
        };
        let got = clustered_attention(&q, &k, &v, &cl);
        let want = full_attention(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn improved_is_never_worse_than_clustered_prop2() {
        let (q, k, _, mut rng) = qkv(48, 16, 16, 3);
        let cl = clustering::cluster_queries(&q, 6, 31, 5, &mut rng);
        let a = full_attention_matrix(&q, &k);
        let a_c = clustered_attention_matrix(&q, &k, &cl);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        for i in 0..48 {
            let j = cl.groups[i] as usize;
            let ec: f32 = (0..48)
                .map(|l| (a_c.at(j, l) - a.at(i, l)).abs())
                .sum();
            let et: f32 = (0..48)
                .map(|l| (a_t.at(i, l) - a.at(i, l)).abs())
                .sum();
            assert!(et <= ec + 1e-4, "row {i}: {et} > {ec}");
        }
    }

    #[test]
    fn improved_matrix_rows_are_distributions() {
        let (q, k, _, mut rng) = qkv(32, 8, 8, 4);
        let cl = clustering::cluster_queries(&q, 4, 31, 5, &mut rng);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        for i in 0..32 {
            let s: f32 = a_t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {i} sums to {s}");
            assert!(a_t.row(i).iter().all(|&w| w >= -1e-6));
        }
    }

    #[test]
    fn improved_attention_output_matches_matrix_times_v() {
        let (q, k, v, mut rng) = qkv(32, 8, 8, 5);
        let cl = clustering::cluster_queries(&q, 4, 31, 5, &mut rng);
        let fast = improved_clustered_attention(&q, &k, &v, &cl, 8);
        let a_t = improved_clustered_attention_matrix(&q, &k, &cl, 8);
        let dense = a_t.matmul(&v);
        assert!(fast.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn oracle_top_with_full_k_is_exact() {
        let (q, k, v, _) = qkv(20, 8, 8, 6);
        let got = oracle_top_attention(&q, &k, &v, 20);
        let want = full_attention(&q, &k, &v);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn reformer_output_is_finite_and_right_shape() {
        let (q, _, v, mut rng) = qkv(64, 16, 16, 7);
        let out = reformer_attention(&q, &v, 2, 16, &mut rng);
        assert_eq!((out.rows, out.cols), (64, 16));
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cost_model_full_is_quadratic_clustered_linear() {
        let full_1k = cost_model(&Variant::Full, 1024, 64, 64);
        let full_2k = cost_model(&Variant::Full, 2048, 64, 64);
        assert_eq!(full_2k.flops, full_1k.flops * 4);
        let cl = Variant::Clustered { clusters: 100, bits: 63, iters: 10 };
        let cl_1k = cost_model(&cl, 1024, 64, 64);
        let cl_2k = cost_model(&cl, 2048, 64, 64);
        assert_eq!(cl_2k.flops, cl_1k.flops * 2);
    }

    #[test]
    fn variant_names_match_paper_notation() {
        assert_eq!(Variant::Full.name(), "full");
        assert_eq!(
            Variant::Clustered { clusters: 100, bits: 63, iters: 10 }.name(),
            "clustered-100"
        );
        assert_eq!(Variant::Lsh { rounds: 4, chunk: 32 }.name(), "lsh-4");
    }
}
