//! Full softmax attention (paper eqs. 1–2) — the exact baseline every
//! approximation is measured against — plus the shared-QK variant the
//! Reformer comparison uses.

use crate::prng::Xoshiro256;
use crate::tensor::Matrix;

use super::{AttentionKernel, Cost};

/// `softmax(QKᵀ/√Dk)·V` — O(N²·D) time, O(N²) memory.
pub fn full_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k); // (N, N)
    logits.scale(scale);
    logits.softmax_rows();
    logits.matmul(v)
}

/// Dense attention matrix (fig. 8 dumps).
pub fn full_attention_matrix(q: &Matrix, k: &Matrix) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k);
    logits.scale(scale);
    logits.softmax_rows();
    logits
}

/// Exact softmax attention kernel.
#[derive(Debug, Clone, Copy, Default)]
pub struct FullAttention;

impl AttentionKernel for FullAttention {
    fn name(&self) -> String {
        "full".into()
    }

    fn run(&self, q: &Matrix, k: &Matrix, v: &Matrix,
           _rng: &mut Xoshiro256) -> Matrix {
        full_attention(q, k, v)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost { flops: n64 * n64 * (dk64 + dv64), bytes: 4 * n64 * n64 }
    }
}

/// Shared-QK exact attention (K := Q), the Reformer-style tying.
#[derive(Debug, Clone, Copy, Default)]
pub struct SharedFullAttention;

impl AttentionKernel for SharedFullAttention {
    fn name(&self) -> String {
        "shared-full".into()
    }

    fn run(&self, q: &Matrix, _k: &Matrix, v: &Matrix,
           _rng: &mut Xoshiro256) -> Matrix {
        full_attention(q, q, v)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        FullAttention.cost(n, dk, dv)
    }
}
