//! ct-contract: bit-exact
//!
//! Full softmax attention (paper eqs. 1–2) — the exact baseline every
//! approximation is measured against — plus the shared-QK variant the
//! Reformer comparison uses.
//!
//! The default path is **streaming**: keys are processed in
//! [`KEY_BLOCK`]-sized blocks with an online-max softmax, so the N×N
//! logits matrix is never materialised — peak extra memory drops from
//! O(N²) to O(N·block) (the packed K panels plus a
//! `QUERY_TILE × KEY_BLOCK` score tile per worker), and N = 4096+ runs
//! on the CPU reference where the dense path would allocate tens of MB
//! per head.  The dense path survives as
//! [`full_attention_materialized`] (bench comparison) and
//! [`full_attention_matrix`] (fig. 8 dumps need the matrix itself).
//!
//! Parallelism follows the compute-core contract: query rows are
//! partitioned over the [`ExecCtx`] pool, each row's key sweep runs
//! left to right in fixed [`KEY_BLOCK`] steps inside one worker, so the
//! reduction order — and therefore every output bit — is independent of
//! the worker count.

use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, gemm, Matrix};

use super::{AttentionKernel, AttnProblem, Cost};

/// Keys per streaming block (multiple of `gemm::NR`).
pub const KEY_BLOCK: usize = 128;
/// Query rows per score tile (multiple of `gemm::MR`).
pub const QUERY_TILE: usize = 16;

/// Streaming `softmax(scale · q·kᵀ) · v` — never materialises the
/// (N_q × N_k) score matrix.
///
/// Two-pass per key block with an online max: each block's scores come
/// from the blocked GEMM tile kernel, the running max `m`, mass `l` and
/// accumulator rescale exactly as in the standard online-softmax
/// recurrence, and the final row is `acc / l` with the same
/// `1/sum.max(1e-30)` guard as the materialised softmax.
pub fn streaming_softmax_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                                   scale: f32, ctx: &ExecCtx) -> Matrix {
    assert_eq!(q.cols, k.cols, "q/k dim mismatch");
    assert_eq!(k.rows, v.rows, "k/v length mismatch");
    let (n_q, d, n_k, dv) = (q.rows, q.cols, k.rows, v.cols);
    let mut out = Matrix::zeros(n_q, dv);
    if n_q == 0 || dv == 0 {
        return out;
    }
    let bp = gemm::pack_nt(k); // O(N_k · d), reused by every worker
    par_rows(ctx, &mut out.data, n_q, dv, |range, chunk| {
        // per-worker scratch: one score tile + per-row online state
        let mut apack = Vec::new();
        let mut s = vec![0f32; QUERY_TILE * KEY_BLOCK];
        let mut mrow = vec![f32::NEG_INFINITY; QUERY_TILE];
        let mut lrow = vec![0f32; QUERY_TILE];
        let mut acc = vec![0f32; QUERY_TILE * dv];
        let mut q0 = range.start;
        while q0 < range.end {
            let qt = QUERY_TILE.min(range.end - q0);
            gemm::pack_a_tile(&q.data, d, q0, qt, d, &mut apack);
            mrow[..qt].fill(f32::NEG_INFINITY);
            lrow[..qt].fill(0.0);
            acc[..qt * dv].fill(0.0);
            let mut j0 = 0;
            while j0 < n_k {
                let kb = KEY_BLOCK.min(n_k - j0);
                gemm::tile_mul(&apack, qt, &bp, j0, kb, &mut s, KEY_BLOCK);
                for r in 0..qt {
                    let srow = &mut s[r * KEY_BLOCK..r * KEY_BLOCK + kb];
                    let mut bm = f32::NEG_INFINITY;
                    for x in srow.iter_mut() {
                        *x *= scale;
                        bm = bm.max(*x);
                    }
                    if bm > mrow[r] {
                        // online max: rescale what's accumulated so far
                        let corr = (mrow[r] - bm).exp();
                        lrow[r] *= corr;
                        for a in &mut acc[r * dv..(r + 1) * dv] {
                            *a *= corr;
                        }
                        mrow[r] = bm;
                    }
                    if mrow[r].is_finite() {
                        let arow = &mut acc[r * dv..(r + 1) * dv];
                        for (jj, &sv) in srow.iter().enumerate() {
                            let w = (sv - mrow[r]).exp();
                            // ct-lint: allow(det-float-accum, reason = "streaming softmax row normaliser; keys are visited in ascending order, which IS the pinned elementary order")
                            lrow[r] += w;
                            axpy(arow, w, v.row(j0 + jj));
                        }
                    }
                }
                j0 += kb;
            }
            for r in 0..qt {
                let dst = &mut chunk[(q0 - range.start + r) * dv..][..dv];
                if n_k > 0 && !mrow[r].is_finite() {
                    // a logit overflowed to ±inf: the accumulator was
                    // zeroed by the exp(m - inf) rescale, so mirror
                    // softmax_inplace's non-finite-max guard instead —
                    // uniform weights over every key
                    let u = 1.0 / n_k as f32;
                    dst.fill(0.0);
                    for j in 0..n_k {
                        axpy(dst, u, v.row(j));
                    }
                    continue;
                }
                let inv = 1.0 / lrow[r].max(1e-30);
                for (o, a) in dst.iter_mut().zip(&acc[r * dv..]) {
                    *o = a * inv;
                }
            }
            q0 += qt;
        }
    });
    out
}

/// `softmax(QKᵀ/√Dk)·V` — exact, streaming, O(N·block) extra memory.
pub fn full_attention(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    full_attention_ctx(q, k, v, &ExecCtx::sequential())
}

/// [`full_attention`] with query rows partitioned over the ctx pool.
pub fn full_attention_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                          ctx: &ExecCtx) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    streaming_softmax_attention(q, k, v, scale, ctx)
}

/// The dense O(N²)-memory path the streaming default replaced: logits →
/// row softmax → matmul.  Kept for the `compute_core` bench comparison
/// and as the equivalence oracle for the streaming tests.
pub fn full_attention_materialized(q: &Matrix, k: &Matrix, v: &Matrix)
                                   -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k); // (N, N)
    logits.scale(scale);
    logits.softmax_rows();
    logits.matmul(v)
}

/// Dense attention matrix (fig. 8 dumps need the matrix itself).
pub fn full_attention_matrix(q: &Matrix, k: &Matrix) -> Matrix {
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut logits = q.matmul_nt(k);
    logits.scale(scale);
    logits.softmax_rows();
    logits
}

/// Exact softmax attention kernel (streaming, never O(N²) memory).
#[derive(Debug, Clone, Copy, Default)]
pub struct FullAttention;

impl AttentionKernel for FullAttention {
    fn name(&self) -> String {
        "full".into()
    }

    /// Masking = solving the valid-prefix sub-problem: the streaming
    /// sweep touches only valid key blocks and only valid query rows
    /// are partitioned, so the masked run is bit-identical to the
    /// unpadded run and the padded output rows come back zero.
    ///
    /// A `query_span` genuinely prunes the compute to O(m·N): each
    /// query row's online-softmax sweep is independent of every other
    /// row (the same per-row invariance the worker-count determinism
    /// property pins down), so streaming only the span rows against
    /// all valid keys emits bits identical to the full solve's span
    /// rows.  This is the incremental-decode hot path.
    fn solve(&self, p: &AttnProblem<'_>, _rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "full does not support causal attention");
        let (q, k, v) = p.valid_qkv();
        if p.is_spanned() {
            let qs = p.span_q();
            return p.restore_span(full_attention_ctx(&qs, &k, &v, ctx));
        }
        p.restore_rows(full_attention_ctx(&q, &k, &v, ctx))
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost {
            flops: n64 * n64 * (dk64 + dv64),
            // streaming working set: packed K panels + one score tile +
            // one accumulator tile per worker — O(N·Dk), not O(N²)
            bytes: 4 * (n64 * dk64
                + (QUERY_TILE * KEY_BLOCK) as u64
                + QUERY_TILE as u64 * dv64),
        }
    }
}

/// Shared-QK exact attention (K := Q), the Reformer-style tying.
#[derive(Debug, Clone, Copy, Default)]
pub struct SharedFullAttention;

impl AttentionKernel for SharedFullAttention {
    fn name(&self) -> String {
        "shared-full".into()
    }

    /// Shared-QK tying composed with the same valid-prefix masking as
    /// [`FullAttention`] (the `k` input is ignored, keys are the valid
    /// queries).  A `query_span` streams only the span rows against
    /// the *full* valid query history as keys — per-row independence
    /// makes that bit-identical to the span rows of the full solve.
    fn solve(&self, p: &AttnProblem<'_>, _rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "shared-full does not support causal attention");
        let (q, _, v) = p.valid_qkv();
        if p.is_spanned() {
            let qs = p.span_q();
            return p.restore_span(full_attention_ctx(&qs, &q, &v, ctx));
        }
        p.restore_rows(full_attention_ctx(&q, &q, &v, ctx))
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        FullAttention.cost(n, dk, dv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::WorkerPool;

    fn qkv(n: usize, dk: usize, dv: usize, seed: u64)
           -> (Matrix, Matrix, Matrix) {
        let mut rng = Xoshiro256::new(seed);
        (Matrix::randn(n, dk, &mut rng), Matrix::randn(n, dk, &mut rng),
         Matrix::randn(n, dv, &mut rng))
    }

    #[test]
    fn streaming_matches_materialized_within_float_noise() {
        // ragged N exercises partial key blocks and query tiles
        for &(n, d) in &[(5, 4), (KEY_BLOCK, 16), (KEY_BLOCK + 37, 8),
                         (3 * KEY_BLOCK + 1, 16)] {
            let (q, k, v) = qkv(n, d, d, n as u64);
            let fast = full_attention(&q, &k, &v);
            let dense = full_attention_materialized(&q, &k, &v);
            let diff = fast.max_abs_diff(&dense);
            assert!(diff < 1e-5, "N={n}: streaming off by {diff}");
        }
    }

    #[test]
    fn streaming_parallel_is_bit_identical_to_sequential() {
        let (q, k, v) = qkv(200, 16, 16, 9);
        let seq = full_attention_ctx(&q, &k, &v, &ExecCtx::sequential());
        for workers in [2, 3, 8] {
            let ctx = ExecCtx::with_par_rows(WorkerPool::new(workers), 1);
            let par = full_attention_ctx(&q, &k, &v, &ctx);
            assert!(par.bit_identical(&seq), "workers={workers}");
        }
    }

    #[test]
    fn long_sequence_runs_through_the_streaming_path() {
        // N = 4096 with a tiny head dim: the dense path would allocate a
        // 16M-element logits matrix; streaming touches O(N·block)
        let (q, k, v) = qkv(4096, 2, 2, 1);
        let out = full_attention(&q, &k, &v);
        assert_eq!((out.rows, out.cols), (4096, 2));
        assert!(out.data.iter().all(|x| x.is_finite()));
        // rows are convex combinations of V rows: bounded by V's range
        let vmax = v.data.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.data.iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.data.iter().all(|&x| x >= vmin - 1e-4
                                        && x <= vmax + 1e-4));
    }

    #[test]
    fn overflowing_logits_fall_back_to_uniform_like_materialized() {
        // q·kᵀ overflows f32 to +inf (same-sign entries, so no inf−inf
        // NaN): softmax_inplace's non-finite-max guard yields uniform
        // weights; streaming must match instead of silently returning
        // zeros
        let mut rng = Xoshiro256::new(5);
        let q = Matrix::from_vec(3, 4, vec![1e20; 12]);
        let k = Matrix::from_vec(8, 4, vec![1e20; 32]);
        let v = Matrix::randn(8, 4, &mut rng);
        let fast = full_attention(&q, &k, &v);
        let dense = full_attention_materialized(&q, &k, &v);
        assert!(dense.data.iter().all(|x| x.is_finite()));
        assert!(fast.max_abs_diff(&dense) < 1e-5,
                "inf-logit fallback diverged from materialized");
    }

    #[test]
    fn empty_keys_yield_zero_rows() {
        let mut rng = Xoshiro256::new(3);
        let q = Matrix::randn(4, 8, &mut rng);
        let k = Matrix::zeros(0, 8);
        let v = Matrix::zeros(0, 8);
        let out =
            streaming_softmax_attention(&q, &k, &v, 1.0,
                                        &ExecCtx::sequential());
        assert_eq!((out.rows, out.cols), (4, 8));
        assert!(out.data.iter().all(|&x| x == 0.0));
    }
}
