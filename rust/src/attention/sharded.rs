//! ct-contract: bit-exact, panic-free
//! ct-lint: allow(panic-index, reason = "split/merge indexing walks offsets derived from the plan's own part lengths (sum of chunk sizes == batch size by construction); new code should prefer get()")
//!
//! Multi-host fan-out: [`ShardedBackend`] splits an [`AttnBatch`]
//! across shard workers and reassembles the replies bit-identically to
//! [`NativeBackend`].
//!
//! ## Why the split preserves the bits
//!
//! The batched determinism contract keys output slice `s = b·H + h` to
//! `slice_stream(seed, s)` — a pure function of the *flat position*,
//! not of which pool (or host) computes it.  A shard therefore receives
//! its sub-batch together with the `slice_base` its slices start at and
//! runs [`solve_batch_offset`], the offset-keyed twin of
//! [`AttentionKernel::solve_batch`]: local slice `s` draws from
//! `slice_stream(seed, slice_base + s)`.  Sequences are split along the
//! batch axis (contiguous chunks); a batch smaller than the fleet
//! splits each sequence's *head* axis instead, which is just a finer
//! slice range.  Session sequences draw from their session streams
//! (`prng::session_seed`, slot-independent) so they can route anywhere
//! without changing a bit — `proptest/attention_props.rs` pins all of
//! this against the single-host oracle.
//!
//! ## Topology
//!
//! - [`ShardEngine`] is the worker-side solver: kernel registry +
//!   per-shard [`KvCache`] behind a [`CachingBackend`].  `ct
//!   shard-worker` serves it over TCP (`server::serve_shard_worker`);
//!   [`InProcessShard`] embeds it for tests and loopback benches.
//! - [`ShardTransport`] is the dispatch seam; [`TcpShard`] implements
//!   it over the wire protocol below.
//! - [`ShardedBackend`] is the gateway-side [`AttentionBackend`]: it
//!   plans the split, dispatches the parts concurrently, and scatters
//!   the replies.  Plain sequences are compacted exactly the way
//!   [`CachingBackend`] compacts its plain flush (PRNG streams keyed by
//!   compacted position), so the gateway can swap this backend in for
//!   its per-bucket `CachingBackend` without changing any output.
//!   Decode sessions route by consistent hash
//!   ([`crate::coordinator::HashRing`]) so a session's cached panels
//!   land on the same host every step.
//!
//! ## Failure semantics
//!
//! Dispatch retries a failed shard `retries` times with doubling
//! backoff, then marks it down and solves the part locally (degraded
//! mode — same bits, single-host speed).  Down shards are skipped when
//! planning until [`ShardedBackend::health_check`] sees them answer a
//! ping.  Session stickiness survives failure: a downed owner's
//! sessions fall back to *local* compute — they are never re-routed to
//! another shard, so no foreign cache state is ever created.
//!
//! ## Wire protocol (shard-worker endpoint)
//!
//! One JSON header line, then raw little-endian f32 frames — tensors
//! are never JSON-encoded on the hot path:
//!
//! ```text
//! {"id":1,"op":"solve","kernel":"full","batch":2,"heads":4,"rows":128,
//!  "dk":32,"dv":32,"seed":"00..0f","slice_base":"0..8",
//!  "lens":[100,128]?,"causal":true?,"cache_quant":"i8-panel"?,
//!  "session":{"id":"..","generation":"..","span_start":96}?}\n
//! <q: B·H·N·Dk f32s> <k: B·H·N·Dk f32s> <v: B·H·N·Dv f32s>
//! ```
//!
//! `causal` is emitted only when `true` and parsed leniently (absent =
//! `false`), so pre-causal gateways and workers interoperate
//! unchanged.  `cache_quant` follows the same discipline: emitted only
//! when the gateway's cache policy is quantized (absent = `"off"`), so
//! pre-quantization headers stay byte-stable.  The field is
//! *declarative* — each worker's own `--cache-quant` governs what its
//! cache actually stores; a mismatch is logged, never an error.
//! Tensor frames are streamed through a fixed-size chunk buffer
//! ([`write_f32s`]) rather than materialised as one frame-sized byte
//! vector per tensor.
//!
//! reply: `{"id":1,"ok":true,"batch":..,"heads":..,"rows":..,"cols":..,
//! "outcome":{..}?,"cache":{"hits":..,"misses":..,"saved_rows":..}?}\n`
//! followed by the output frame, or `{"id", "error"}` with no frame.
//! `cache` rides session replies only: a cumulative snapshot of the
//! worker's cache counters ([`ShardCacheStats`]), parsed leniently so
//! pre-counter workers interoperate unchanged.  `{"op":"ping"}` →
//! `{"ok":true}` and `{"op":"end","session":"<hex>"}` → `{"ok":true}`
//! share the framing.  Seeds, session ids and generations travel as
//! 16-hex-digit strings: JSON numbers are f64 and silently round u64s
//! above 2^53, which would break bit-identity.  (Cache counters *are*
//! plain numbers — they are telemetry, not part of the bit contract.)

// The panic-free serving contract, compiler-side: `ct lint` scans the
// source, clippy guards what the scanner cannot see through macros.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::ring::HashRing;
use crate::exec::{ExecCtx, WorkerPool};
use crate::jsonio::{obj, parse, Value};
use crate::prng::slice_stream;
use crate::tensor::batch::BatchMatrix;

use super::backend::AttentionBackend;
use super::cache::{CacheQuant, CachingBackend, KvCache, KvCacheOptions,
                   SeqOutcome};
use super::problem::{AttnBatch, AttnProblem, CacheRef, SessionRef};
use super::{kernel_for, AttentionKernel, Variant};

// ---------------------------------------------------------------------------
// offset-keyed batch solve
// ---------------------------------------------------------------------------

/// [`AttentionKernel::solve_batch`] with the PRNG streams keyed at an
/// offset: local slice `s` draws from `slice_stream(seed, slice_base +
/// s)`.  With `slice_base = 0` this *is* `solve_batch`; with the base
/// of a sub-batch's first slice it reproduces the slices a single-host
/// solve would have produced at those flat positions — the primitive
/// that makes the shard split bit-invisible.
pub fn solve_batch_offset(kernel: &dyn AttentionKernel,
                          batch: &AttnBatch<'_>, slice_base: u64,
                          ctx: &ExecCtx) -> BatchMatrix {
    batch.validate();
    let (q, k, v) = (batch.q, batch.k, batch.v);
    let mut out = BatchMatrix::zeros(q.batch, q.heads, q.rows, v.cols);
    if out.slices() == 0 || out.slice_len() == 0 {
        return out;
    }
    let (outer, inner) = ctx.split_batch(out.slices());
    let dv = v.cols;
    let chunks = out.slices_mut();
    outer.for_each_mut(chunks, |s, chunk: &mut [f32]| {
        let mut rng = slice_stream(batch.seed, slice_base + s as u64);
        let l = batch.slice_valid_len(s);
        let (qs, ks, vs) =
            (q.slice_valid(s, l), k.slice_valid(s, l),
             v.slice_valid(s, l));
        let o = kernel.solve(&AttnProblem::new(&qs, &ks, &vs)
                                 .with_causal(batch.causal),
                             &mut rng, &inner);
        chunk[..l * dv].copy_from_slice(&o.data);
    });
    out
}

// ---------------------------------------------------------------------------
// request/reply types + transport seam
// ---------------------------------------------------------------------------

/// Session annotation of a shard request (the wire form of
/// [`SessionRef`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSession {
    pub session: u64,
    pub generation: u64,
    pub span_start: usize,
}

/// One dispatchable sub-problem: a dense (sub-)batch plus the
/// `slice_base` its PRNG streams start at.  `session` marks a one-
/// sequence decode step (its streams come from the session instead).
pub struct ShardRequest {
    pub kernel: String,
    pub q: BatchMatrix,
    pub k: BatchMatrix,
    pub v: BatchMatrix,
    pub seed: u64,
    pub slice_base: u64,
    pub lens: Option<Vec<usize>>,
    /// Autoregressive masking — only causal-capable kernels (the linear
    /// family) accept it; the engine rejects the rest with an error.
    pub causal: bool,
    /// The gateway's panel storage policy, declared for observability.
    /// Each worker's own cache policy governs what it actually stores;
    /// a mismatch is logged, never an error (module docs).
    pub cache_quant: CacheQuant,
    pub session: Option<ShardSession>,
}

/// Cumulative snapshot of a shard worker's cache counters, returned on
/// session replies (the optional `"cache"` reply field).  Telemetry
/// only — the gateway aggregates these into its bucket report; they
/// never influence an output bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Prefix rows the worker did *not* recompute thanks to hits.
    pub saved_rows: u64,
}

/// A shard's answer: the sub-batch output, plus the cache outcome (and
/// a counter snapshot) when the request was a session step.
pub struct ShardReply {
    pub out: BatchMatrix,
    pub outcome: Option<SeqOutcome>,
    pub cache: Option<ShardCacheStats>,
}

/// How [`ShardedBackend`] reaches one shard — in-process for tests and
/// loopback benches, TCP for real fleets.
pub trait ShardTransport: Send + Sync {
    /// Stable identity — the consistent-hash ring hashes this, so it
    /// must not change across gateway restarts (use the address).
    fn shard_id(&self) -> String;

    fn execute(&self, req: &ShardRequest) -> Result<ShardReply>;

    /// Liveness probe for [`ShardedBackend::health_check`].
    fn ping(&self) -> bool;

    /// Release a session's cached state on this shard.
    fn end_session(&self, session: u64) -> Result<()>;
}

// ---------------------------------------------------------------------------
// worker-side engine
// ---------------------------------------------------------------------------

/// A shard request's kernel, resolved once and reused: the raw kernel
/// for plain parts, a [`CachingBackend`] for session steps.
struct KernelEntry {
    kernel: Box<dyn AttentionKernel>,
    cached: CachingBackend,
}

/// The worker-side solver behind `ct shard-worker` (and
/// [`InProcessShard`]): resolves kernels by name on demand and executes
/// [`ShardRequest`]s against a shard-local [`KvCache`].
pub struct ShardEngine {
    workers: usize,
    cache: Arc<KvCache>,
    kernels: Mutex<BTreeMap<String, Arc<KernelEntry>>>,
}

impl ShardEngine {
    /// Engine over an unbounded cache.  `workers` sizes the solve pool
    /// (`0` = one per hardware thread, `1` = sequential).
    pub fn new(workers: usize) -> Self {
        Self::with_cache(workers, Arc::new(KvCache::unbounded()))
    }

    pub fn with_cache(workers: usize, cache: Arc<KvCache>) -> Self {
        Self { workers, cache, kernels: Mutex::new(BTreeMap::new()) }
    }

    pub fn cache(&self) -> &Arc<KvCache> {
        &self.cache
    }

    /// Cumulative cache counters in wire form — the `"cache"` field of
    /// session replies.
    pub fn cache_stats(&self) -> ShardCacheStats {
        let c = self.cache.counters();
        ShardCacheStats {
            hits: c.hits.load(Ordering::Relaxed),
            misses: c.misses.load(Ordering::Relaxed),
            saved_rows: c.reused_rows.load(Ordering::Relaxed),
        }
    }

    fn ctx(&self) -> ExecCtx {
        match self.workers {
            0 => ExecCtx::new(WorkerPool::auto()),
            1 => ExecCtx::sequential(),
            n => ExecCtx::new(WorkerPool::new(n)),
        }
    }

    fn entry(&self, name: &str) -> Result<Arc<KernelEntry>> {
        let mut kernels = crate::exec::lock_unpoisoned(&self.kernels);
        if let Some(e) = kernels.get(name) {
            return Ok(e.clone());
        }
        let variant = Variant::parse(name)
            .ok_or_else(|| anyhow!("unknown kernel {name:?}"))?;
        let cached = CachingBackend::native(name, self.cache.clone())
            .ok_or_else(|| anyhow!("unknown kernel {name:?}"))?;
        let e = Arc::new(KernelEntry { kernel: kernel_for(&variant),
                                       cached });
        kernels.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Execute one shard request.  This is the worker's trust boundary:
    /// malformed requests come back as `Err` (one error reply on the
    /// wire), never as a panic that kills the connection thread.
    pub fn solve(&self, req: &ShardRequest) -> Result<ShardReply> {
        let entry = self.entry(&req.kernel)?;
        let (q, k, v) = (&req.q, &req.k, &req.v);
        if (q.batch, q.heads, q.rows) != (k.batch, k.heads, k.rows)
            || (q.batch, q.heads, q.rows) != (v.batch, v.heads, v.rows)
            || q.cols != k.cols
        {
            return Err(anyhow!("q/k/v shape mismatch"));
        }
        if let Some(lens) = &req.lens {
            if lens.len() != q.batch {
                return Err(anyhow!("lens has {} entries for batch {}",
                                   lens.len(), q.batch));
            }
            if lens.iter().any(|&l| l == 0 || l > q.rows) {
                return Err(anyhow!("lens entry out of 1..={}", q.rows));
            }
        }
        if req.causal && !entry.kernel.supports_causal() {
            // part of the trust boundary: an error reply, not the
            // assert the kernel itself would raise
            return Err(anyhow!("kernel {:?} does not support causal \
                                attention", req.kernel));
        }
        let ctx = self.ctx();
        match req.session {
            None => {
                let mut batch = AttnBatch::new(q, k, v, req.seed)
                    .with_causal(req.causal);
                if let Some(lens) = req.lens.as_deref() {
                    batch = batch.with_lens(lens);
                }
                Ok(ShardReply {
                    out: solve_batch_offset(entry.kernel.as_ref(), &batch,
                                            req.slice_base, &ctx),
                    outcome: None,
                    cache: None,
                })
            }
            Some(s) => {
                if q.batch != 1 {
                    return Err(anyhow!("session request must carry \
                                        exactly one sequence"));
                }
                if req.cache_quant != self.cache.quant() {
                    // declarative field (module docs): the worker's own
                    // policy wins; the mismatch is only worth a log line
                    log::debug!("request declares cache-quant {} but \
                                 this worker stores {}",
                                req.cache_quant.name(),
                                self.cache.quant().name());
                }
                let valid = req.lens.as_ref().map_or(q.rows, |l| l[0]);
                if s.span_start >= valid {
                    return Err(anyhow!("span_start {} leaves no row in \
                                        0..{valid}", s.span_start));
                }
                let sessions = [Some(SessionRef {
                    cache: CacheRef { session: s.session,
                                      generation: s.generation },
                    span_start: s.span_start,
                })];
                let lens = [valid];
                let batch = AttnBatch::new(q, k, v, req.seed)
                    .with_lens(&lens)
                    .with_sessions(&sessions)
                    .with_causal(req.causal);
                let (out, outcomes) =
                    entry.cached.execute_with_report(&batch, &ctx);
                Ok(ShardReply { out, outcome: Some(outcomes[0]),
                                cache: Some(self.cache_stats()) })
            }
        }
    }

    /// Release a session's cached panels.
    pub fn end_session(&self, session: u64) {
        self.cache.invalidate(session);
    }
}

/// Loopback transport: a [`ShardEngine`] called directly.  Used by
/// tests, the sharded bench (`CT_SMOKE` CI runs no real network) and
/// single-host smoke deployments.
pub struct InProcessShard {
    id: String,
    engine: Arc<ShardEngine>,
}

impl InProcessShard {
    pub fn new(id: &str, engine: Arc<ShardEngine>) -> Self {
        Self { id: id.to_string(), engine }
    }
}

impl ShardTransport for InProcessShard {
    fn shard_id(&self) -> String {
        self.id.clone()
    }

    fn execute(&self, req: &ShardRequest) -> Result<ShardReply> {
        self.engine.solve(req)
    }

    fn ping(&self) -> bool {
        true
    }

    fn end_session(&self, session: u64) -> Result<()> {
        self.engine.end_session(session);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// wire encoding (shared with server::serve_shard_worker)
// ---------------------------------------------------------------------------

/// u64 → 16 hex digits.  Never encode a u64 as a JSON number: `Value`
/// numbers are f64 and round above 2^53, which would corrupt seeds and
/// session ids — and with them, the bits.
pub(crate) fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

pub(crate) fn parse_hex_u64(v: &Value) -> Result<u64> {
    let s = v.as_str()
        .ok_or_else(|| anyhow!("expected a hex-string u64"))?;
    u64::from_str_radix(s, 16)
        .map_err(|e| anyhow!("bad hex u64 {s:?}: {e}"))
}

/// Write one raw little-endian f32 frame, pipelined: the floats stream
/// through a fixed 32 KiB chunk buffer instead of materialising a
/// second frame-sized byte vector per tensor, so writer memory is O(1)
/// in frame size and the first chunks reach the socket while later
/// ones are still being encoded.
pub(crate) fn write_f32s(w: &mut impl Write, xs: &[f32])
                         -> std::io::Result<()> {
    const CHUNK_ELEMS: usize = 8192; // 32 KiB per write
    let mut buf = Vec::with_capacity(CHUNK_ELEMS.min(xs.len()) * 4);
    for chunk in xs.chunks(CHUNK_ELEMS) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Read exactly `n` little-endian f32s.
pub(crate) fn read_f32s(r: &mut impl Read, n: usize)
                        -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// The `"op":"solve"` header line of a request.
fn solve_header(id: i64, req: &ShardRequest) -> Value {
    let mut fields = vec![
        ("id", id.into()),
        ("op", "solve".into()),
        ("kernel", req.kernel.as_str().into()),
        ("batch", req.q.batch.into()),
        ("heads", req.q.heads.into()),
        ("rows", req.q.rows.into()),
        ("dk", req.q.cols.into()),
        ("dv", req.v.cols.into()),
        ("seed", hex_u64(req.seed).into()),
        ("slice_base", hex_u64(req.slice_base).into()),
    ];
    if let Some(lens) = &req.lens {
        fields.push(("lens", lens.clone().into()));
    }
    if req.causal {
        // emitted only when set: a non-causal header is byte-identical
        // to the pre-causal protocol
        fields.push(("causal", true.into()));
    }
    if req.cache_quant != CacheQuant::Off {
        // same discipline: an unquantized header is byte-identical to
        // the pre-quantization protocol
        fields.push(("cache_quant", req.cache_quant.name().into()));
    }
    if let Some(s) = &req.session {
        fields.push(("session", obj(vec![
            ("id", hex_u64(s.session).into()),
            ("generation", hex_u64(s.generation).into()),
            ("span_start", s.span_start.into()),
        ])));
    }
    obj(fields)
}

/// Parsed `"op":"solve"` header — everything but the tensor frames.
pub(crate) struct SolveHeader {
    pub id: i64,
    pub kernel: String,
    pub batch: usize,
    pub heads: usize,
    pub rows: usize,
    pub dk: usize,
    pub dv: usize,
    pub seed: u64,
    pub slice_base: u64,
    pub lens: Option<Vec<usize>>,
    pub causal: bool,
    pub cache_quant: CacheQuant,
    pub session: Option<ShardSession>,
}

impl SolveHeader {
    pub(crate) fn parse(req: &Value) -> Result<Self> {
        let field = |k: &str| {
            req.get(k).as_usize().ok_or_else(|| anyhow!("missing {k}"))
        };
        let lens = match req.get("lens") {
            Value::Null => None,
            Value::Arr(a) => Some(
                a.iter()
                    .map(|v| v.as_usize()
                         .ok_or_else(|| anyhow!("bad lens entry")))
                    .collect::<Result<Vec<usize>>>()?),
            _ => return Err(anyhow!("lens must be an array")),
        };
        let session = match req.get("session") {
            Value::Null => None,
            s => Some(ShardSession {
                session: parse_hex_u64(s.get("id"))?,
                generation: parse_hex_u64(s.get("generation"))?,
                span_start: s.get("span_start").as_usize()
                    .ok_or_else(|| anyhow!("missing span_start"))?,
            }),
        };
        Ok(Self {
            id: req.get("id").as_i64().unwrap_or(0),
            kernel: req.get("kernel").as_str()
                .ok_or_else(|| anyhow!("missing kernel"))?
                .to_string(),
            batch: field("batch")?,
            heads: field("heads")?,
            rows: field("rows")?,
            dk: field("dk")?,
            dv: field("dv")?,
            seed: parse_hex_u64(req.get("seed"))?,
            slice_base: parse_hex_u64(req.get("slice_base"))?,
            lens,
            // lenient: absent (pre-causal peers) means false
            causal: req.get("causal").as_bool().unwrap_or(false),
            // lenient: absent (pre-quantization peers) means off; a
            // peer that *does* declare a mode must be understood
            cache_quant: match req.get("cache_quant") {
                Value::Null => CacheQuant::Off,
                v => v.as_str().and_then(CacheQuant::parse)
                    .ok_or_else(|| anyhow!("bad cache_quant"))?,
            },
            session,
        })
    }

    /// Elements of one tensor frame of column width `cols` — `None` on
    /// overflow or past the sanity cap, so a hostile header can never
    /// make the worker allocate unbounded memory.
    pub(crate) fn payload_elems(&self, cols: usize) -> Option<usize> {
        const MAX_ELEMS: usize = 1 << 28; // 1 GiB of f32 per frame
        let n = self.batch.checked_mul(self.heads)?
            .checked_mul(self.rows)?
            .checked_mul(cols)?;
        (n <= MAX_ELEMS).then_some(n)
    }
}

/// JSON form of a [`SeqOutcome`] (the `"outcome"` reply field).
pub(crate) fn outcome_to_value(o: &SeqOutcome) -> Value {
    match o {
        SeqOutcome::Bypass => obj(vec![("kind", "bypass".into())]),
        SeqOutcome::Hit { reused_rows, computed_rows, reclustered } => {
            obj(vec![
                ("kind", "hit".into()),
                ("reused_rows", (*reused_rows).into()),
                ("computed_rows", (*computed_rows).into()),
                ("reclustered", (*reclustered).into()),
            ])
        }
        SeqOutcome::Miss { recomputed_rows } => obj(vec![
            ("kind", "miss".into()),
            ("recomputed_rows", (*recomputed_rows).into()),
        ]),
    }
}

/// JSON form of a [`ShardCacheStats`] (the `"cache"` reply field).
/// Plain numbers, not hex strings: counters are telemetry, and a
/// decode fleet retires the sun long before one crosses 2^53.
pub(crate) fn cache_stats_to_value(c: &ShardCacheStats) -> Value {
    obj(vec![
        ("hits", (c.hits as usize).into()),
        ("misses", (c.misses as usize).into()),
        ("saved_rows", (c.saved_rows as usize).into()),
    ])
}

/// Lenient inverse of [`cache_stats_to_value`]: missing or malformed
/// counters read as zero rather than failing the reply.
pub(crate) fn cache_stats_from_value(v: &Value) -> ShardCacheStats {
    let field = |k: &str| v.get(k).as_usize().unwrap_or(0) as u64;
    ShardCacheStats {
        hits: field("hits"),
        misses: field("misses"),
        saved_rows: field("saved_rows"),
    }
}

pub(crate) fn outcome_from_value(v: &Value) -> Result<SeqOutcome> {
    let field = |k: &str| {
        v.get(k).as_usize().ok_or_else(|| anyhow!("outcome missing {k}"))
    };
    match v.get("kind").as_str() {
        Some("bypass") => Ok(SeqOutcome::Bypass),
        Some("hit") => Ok(SeqOutcome::Hit {
            reused_rows: field("reused_rows")?,
            computed_rows: field("computed_rows")?,
            reclustered: v.get("reclustered").as_bool().unwrap_or(false),
        }),
        Some("miss") => Ok(SeqOutcome::Miss {
            recomputed_rows: field("recomputed_rows")?,
        }),
        other => Err(anyhow!("unknown outcome kind {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------------

struct ShardConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One shard worker over the wire protocol (module docs).  Connects
/// lazily, holds one connection, and drops it after any failed
/// exchange — the binary framing makes a half-consumed stream
/// unrecoverable, and reconnecting is cheap next to a solve.  Retry
/// policy lives in [`ShardedBackend`], not here: one call, one attempt.
pub struct TcpShard {
    addr: String,
    conn: Mutex<Option<ShardConn>>,
    next_id: AtomicU64,
}

impl TcpShard {
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
            conn: Mutex::new(None),
            next_id: AtomicU64::new(1),
        }
    }

    fn with_conn<R>(&self, f: impl FnOnce(&mut ShardConn) -> Result<R>)
                    -> Result<R> {
        let mut guard = crate::exec::lock_unpoisoned(&self.conn);
        if guard.is_none() {
            let stream = TcpStream::connect(&self.addr)?;
            *guard = Some(ShardConn {
                reader: BufReader::new(stream.try_clone()?),
                writer: stream,
            });
        }
        let Some(conn) = guard.as_mut() else {
            return Err(anyhow!("shard connection unavailable"));
        };
        match f(conn) {
            Ok(r) => Ok(r),
            Err(e) => {
                // framing state unknown after a failure: reconnect on
                // the next call
                *guard = None;
                Err(e)
            }
        }
    }

    fn round_trip_line(&self, conn: &mut ShardConn, header: Value)
                       -> Result<Value> {
        conn.writer.write_all(header.to_string().as_bytes())?;
        conn.writer.write_all(b"\n")?;
        conn.writer.flush()?;
        let mut line = String::new();
        if conn.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("shard closed the connection"));
        }
        let reply = parse(&line).map_err(|e| anyhow!("bad reply: {e}"))?;
        if let Some(err) = reply.get("error").as_str() {
            return Err(anyhow!("shard error: {err}"));
        }
        Ok(reply)
    }
}

impl ShardTransport for TcpShard {
    fn shard_id(&self) -> String {
        self.addr.clone()
    }

    fn execute(&self, req: &ShardRequest) -> Result<ShardReply> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as i64;
        let header = solve_header(id, req);
        let want = (req.q.batch, req.q.heads, req.q.rows, req.v.cols);
        self.with_conn(|conn| {
            conn.writer.write_all(header.to_string().as_bytes())?;
            conn.writer.write_all(b"\n")?;
            write_f32s(&mut conn.writer, &req.q.data)?;
            write_f32s(&mut conn.writer, &req.k.data)?;
            write_f32s(&mut conn.writer, &req.v.data)?;
            conn.writer.flush()?;
            let mut line = String::new();
            if conn.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("shard closed the connection"));
            }
            let reply =
                parse(&line).map_err(|e| anyhow!("bad reply: {e}"))?;
            if let Some(err) = reply.get("error").as_str() {
                return Err(anyhow!("shard error: {err}"));
            }
            if reply.get("id").as_i64() != Some(id) {
                return Err(anyhow!("reply id mismatch"));
            }
            let dim = |k: &str| {
                reply.get(k).as_usize()
                    .ok_or_else(|| anyhow!("reply missing {k}"))
            };
            let got = (dim("batch")?, dim("heads")?, dim("rows")?,
                       dim("cols")?);
            if got != want {
                return Err(anyhow!("reply shape {got:?} != {want:?}"));
            }
            let data = read_f32s(&mut conn.reader,
                                 got.0 * got.1 * got.2 * got.3)?;
            let outcome = match reply.get("outcome") {
                Value::Null => None,
                v => Some(outcome_from_value(v)?),
            };
            // lenient: pre-counter workers simply omit the field
            let cache = match reply.get("cache") {
                Value::Null => None,
                c => Some(cache_stats_from_value(c)),
            };
            Ok(ShardReply {
                out: BatchMatrix::from_vec(got.0, got.1, got.2, got.3,
                                           data),
                outcome,
                cache,
            })
        })
    }

    fn ping(&self) -> bool {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as i64;
        let header = obj(vec![("id", id.into()), ("op", "ping".into())]);
        self.with_conn(|conn| {
            let reply = self.round_trip_line(conn, header)?;
            (reply.get("ok").as_bool() == Some(true))
                .then_some(())
                .ok_or_else(|| anyhow!("ping not acknowledged"))
        })
        .is_ok()
    }

    fn end_session(&self, session: u64) -> Result<()> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as i64;
        let header = obj(vec![
            ("id", id.into()),
            ("op", "end".into()),
            ("session", hex_u64(session).into()),
        ]);
        self.with_conn(|conn| {
            self.round_trip_line(conn, header).map(|_| ())
        })
    }
}

// ---------------------------------------------------------------------------
// gateway-side fan-out backend
// ---------------------------------------------------------------------------

/// Dispatch policy of a [`ShardedBackend`].
#[derive(Debug, Clone, Copy)]
pub struct ShardOptions {
    /// Re-dispatch attempts after a failed shard exchange (on top of
    /// the first try) before the part falls back to local compute.
    pub retries: usize,
    /// Sleep before the first retry; doubles per attempt.
    pub backoff: Duration,
    /// Virtual nodes per shard on the session-routing ring.
    pub vnodes: usize,
    /// Panel storage policy declared on every dispatched request and
    /// applied to the gateway's own degraded-mode cache.  Workers run
    /// whatever their `--cache-quant` says (module docs); keeping the
    /// fleet and the gateway on one setting is a deployment concern.
    pub cache_quant: CacheQuant,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            retries: 2,
            backoff: Duration::from_millis(10),
            vnodes: HashRing::DEFAULT_VNODES,
            cache_quant: CacheQuant::Off,
        }
    }
}

/// One contiguous (sequence-range × head-range) block of the compacted
/// plain batch.  The planner's invariant: a part spanning more than one
/// sequence always carries every head, so a part's slices are
/// contiguous in flat `b·H + h` order and one `slice_base` keys them
/// all.
struct Part {
    /// Position into the compacted sequence list.
    seq0: usize,
    nseq: usize,
    head0: usize,
    nheads: usize,
}

/// Split `nseq` sequences × `heads` heads across `shards` parts: batch
/// axis first (contiguous chunks, sizes within one), head axis when the
/// batch alone cannot feed every shard (`nseq < shards`).
fn plan_parts(nseq: usize, heads: usize, shards: usize) -> Vec<Part> {
    let shards = shards.max(1);
    if nseq == 0 || heads == 0 {
        return Vec::new();
    }
    if nseq >= shards {
        let (base, extra) = (nseq / shards, nseq % shards);
        let mut parts = Vec::with_capacity(shards);
        let mut s0 = 0;
        for i in 0..shards {
            let n = base + usize::from(i < extra);
            parts.push(Part { seq0: s0, nseq: n, head0: 0,
                              nheads: heads });
            s0 += n;
        }
        parts
    } else {
        // fewer sequences than shards: split each sequence's head axis
        let per_seq = (shards / nseq).min(heads).max(1);
        let (base, extra) = (heads / per_seq, heads % per_seq);
        let mut parts = Vec::with_capacity(nseq * per_seq);
        for s in 0..nseq {
            let mut h0 = 0;
            for i in 0..per_seq {
                let nh = base + usize::from(i < extra);
                parts.push(Part { seq0: s, nseq: 1, head0: h0,
                                  nheads: nh });
                h0 += nh;
            }
        }
        parts
    }
}

/// Gather head range `head0..head0+nheads` of the listed sequences
/// (original batch indices) into a dense sub-batch.
fn gather_part(t: &BatchMatrix, seqs: &[usize], head0: usize,
               nheads: usize) -> BatchMatrix {
    let mut out = BatchMatrix::zeros(seqs.len(), nheads, t.rows, t.cols);
    for (pos, &b) in seqs.iter().enumerate() {
        for hh in 0..nheads {
            out.slice_mut(pos * nheads + hh)
                .copy_from_slice(t.view(b * t.heads + head0 + hh).data);
        }
    }
    out
}

/// One dispatch unit: a gathered sub-request, its target shard, and
/// where the reply's slices scatter back to.
struct Job {
    /// Original batch indices of the gathered sequences.
    seqs: Vec<usize>,
    head0: usize,
    nheads: usize,
    /// Transport index; `None` = forced local (every shard down, or a
    /// downed session owner — stickiness forbids re-routing sessions).
    shard: Option<usize>,
    req: ShardRequest,
    /// Original batch index when the job is one session sequence.
    session_seq: Option<usize>,
}

/// Fan-out [`AttentionBackend`]: splits each descriptor across shard
/// workers, dispatches the parts concurrently, and reassembles the
/// replies bit-identically to [`NativeBackend`] (module docs).
///
/// [`NativeBackend`]: super::backend::NativeBackend
pub struct ShardedBackend {
    kernel_name: String,
    kernel: Box<dyn AttentionKernel>,
    /// Degraded-mode solver (down shards, downed session owners).
    local: CachingBackend,
    transports: Vec<Box<dyn ShardTransport>>,
    /// `transports[i].shard_id()`, transport order.
    ids: Vec<String>,
    /// Liveness map, transport order; flips down after exhausted
    /// retries, back up on success or a good health-check ping.
    down: Vec<AtomicBool>,
    /// Latest counter snapshot per shard, transport order — refreshed
    /// whenever a session reply carries one.
    stats: Vec<Mutex<ShardCacheStats>>,
    ring: HashRing,
    opts: ShardOptions,
}

impl ShardedBackend {
    /// Fan out over explicit transports (`None` on an unknown kernel or
    /// an empty fleet).
    pub fn from_transports(kernel: &str,
                           transports: Vec<Box<dyn ShardTransport>>,
                           opts: ShardOptions) -> Option<Self> {
        if transports.is_empty() {
            return None;
        }
        let variant = Variant::parse(kernel)?;
        let ids: Vec<String> =
            transports.iter().map(|t| t.shard_id()).collect();
        // the degraded-mode cache follows the fleet's storage policy so
        // a session falling back locally sees the same numerics
        let local = CachingBackend::native(
            kernel,
            Arc::new(KvCache::new(KvCacheOptions {
                quant: opts.cache_quant,
                ..KvCacheOptions::default()
            })))?;
        Some(Self {
            kernel_name: kernel.to_string(),
            kernel: kernel_for(&variant),
            local,
            down: transports.iter().map(|_| AtomicBool::new(false))
                .collect(),
            stats: transports.iter()
                .map(|_| Mutex::new(ShardCacheStats::default()))
                .collect(),
            ring: HashRing::new(&ids, opts.vnodes.max(1)),
            ids,
            transports,
            opts,
        })
    }

    /// `shards` in-process loopback workers, each with its own engine
    /// and cache — the test/bench topology.
    pub fn in_process(kernel: &str, shards: usize,
                      workers_per_shard: usize) -> Option<Self> {
        Self::in_process_with(kernel, shards, workers_per_shard,
                              ShardOptions::default())
    }

    /// [`Self::in_process`] under explicit options; each loopback
    /// engine's cache follows `opts.cache_quant`, mirroring a fleet of
    /// workers started with the matching `--cache-quant`.
    pub fn in_process_with(kernel: &str, shards: usize,
                           workers_per_shard: usize, opts: ShardOptions)
                           -> Option<Self> {
        let transports: Vec<Box<dyn ShardTransport>> = (0..shards.max(1))
            .map(|i| {
                let cache = Arc::new(KvCache::new(KvCacheOptions {
                    quant: opts.cache_quant,
                    ..KvCacheOptions::default()
                }));
                Box::new(InProcessShard::new(
                    &format!("local-{i}"),
                    Arc::new(ShardEngine::with_cache(workers_per_shard,
                                                     cache)),
                )) as Box<dyn ShardTransport>
            })
            .collect();
        Self::from_transports(kernel, transports, opts)
    }

    /// Fan out over `ct shard-worker` hosts.
    pub fn over_tcp(kernel: &str, addrs: &[String], opts: ShardOptions)
                    -> Option<Self> {
        let transports: Vec<Box<dyn ShardTransport>> = addrs
            .iter()
            .map(|a| Box::new(TcpShard::new(a)) as Box<dyn ShardTransport>)
            .collect();
        Self::from_transports(kernel, transports, opts)
    }

    /// Shard identities, transport order.
    pub fn shard_ids(&self) -> &[String] {
        &self.ids
    }

    /// The session-routing ring.
    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    pub fn options(&self) -> ShardOptions {
        self.opts
    }

    /// Fleet-wide cache counters: the latest per-shard reply snapshot
    /// summed with the gateway's local degraded-mode cache.  Snapshots
    /// ride session replies (satellite telemetry, not a synchronous
    /// poll), so the figures can trail in-flight work by one step.
    pub fn cache_stats(&self) -> ShardCacheStats {
        let mut total = ShardCacheStats::default();
        for s in &self.stats {
            let s = crate::exec::lock_unpoisoned(s);
            total.hits += s.hits;
            total.misses += s.misses;
            total.saved_rows += s.saved_rows;
        }
        let c = self.local.cache().counters();
        total.hits += c.hits.load(Ordering::Relaxed);
        total.misses += c.misses.load(Ordering::Relaxed);
        total.saved_rows += c.reused_rows.load(Ordering::Relaxed);
        total
    }

    /// Ping every shard and refresh the liveness map; returns per-shard
    /// liveness in transport order.  A recovered shard starts receiving
    /// parts (and its sessions) again right away.
    pub fn health_check(&self) -> Vec<bool> {
        (0..self.transports.len())
            .map(|i| {
                let up = self.transports[i].ping();
                self.down[i].store(!up, Ordering::Relaxed);
                up
            })
            .collect()
    }

    /// Release a session's cached state on its owning shard (and in the
    /// local degraded-mode cache, in case any step fell back).
    pub fn end_session(&self, session: u64) {
        if let Some(i) = self.owner_index(session) {
            if let Err(e) = self.transports[i].end_session(session) {
                log::debug!("end_session({session}) on {}: {e:#}",
                            self.ids[i]);
            }
        }
        self.local.cache().invalidate(session);
    }

    /// Transport index of the ring owner of `session`.
    fn owner_index(&self, session: u64) -> Option<usize> {
        self.ring.owner_id(session)
            .and_then(|oid| self.ids.iter().position(|id| id == oid))
    }

    /// Execute one descriptor and report, per sequence, how the cache
    /// treated it — the sharded twin of
    /// [`CachingBackend::execute_with_report`].
    pub fn execute_with_report(&self, batch: &AttnBatch<'_>,
                               ctx: &ExecCtx)
                               -> (BatchMatrix, Vec<SeqOutcome>) {
        batch.validate();
        let (q, k, v) = (batch.q, batch.k, batch.v);
        let (bsz, heads) = (q.batch, q.heads);
        let dv = v.cols;
        let mut out = BatchMatrix::zeros(bsz, heads, q.rows, dv);
        let mut outcomes = vec![SeqOutcome::Bypass; bsz];
        if out.slices() == 0 || out.slice_len() == 0 {
            return (out, outcomes);
        }

        // plain sequences are compacted exactly like CachingBackend's
        // plain flush: PRNG streams keyed by *compacted* position, so
        // this backend is a drop-in for the gateway's per-bucket
        // CachingBackend (and, all-plain, for NativeBackend)
        let plain: Vec<usize> = (0..bsz)
            .filter(|&b| batch.sessions.map_or(true, |ss| ss[b].is_none()))
            .collect();
        let healthy: Vec<usize> = (0..self.transports.len())
            .filter(|&i| !self.down[i].load(Ordering::Relaxed))
            .collect();

        let mut jobs: Vec<Job> = Vec::new();
        let parts =
            plan_parts(plain.len(), heads, healthy.len().max(1));
        for (pi, part) in parts.into_iter().enumerate() {
            let seqs: Vec<usize> =
                plain[part.seq0..part.seq0 + part.nseq].to_vec();
            let lens = batch.lens.map(|ls| {
                seqs.iter().map(|&b| ls[b]).collect::<Vec<usize>>()
            });
            let req = ShardRequest {
                kernel: self.kernel_name.clone(),
                q: gather_part(q, &seqs, part.head0, part.nheads),
                k: gather_part(k, &seqs, part.head0, part.nheads),
                v: gather_part(v, &seqs, part.head0, part.nheads),
                seed: batch.seed,
                slice_base: (part.seq0 * heads + part.head0) as u64,
                lens,
                causal: batch.causal,
                cache_quant: self.opts.cache_quant,
                session: None,
            };
            // one part per healthy shard (the planner emits at most
            // `healthy.len()` parts, so this never doubles up)
            let shard = (!healthy.is_empty())
                .then(|| healthy[pi % healthy.len()]);
            jobs.push(Job { seqs, head0: part.head0,
                            nheads: part.nheads, shard, req,
                            session_seq: None });
        }

        if let Some(sessions) = batch.sessions {
            for b in 0..bsz {
                let Some(sref) = sessions[b] else { continue };
                let valid = batch.valid_len(b);
                let seqs = vec![b];
                let req = ShardRequest {
                    kernel: self.kernel_name.clone(),
                    q: gather_part(q, &seqs, 0, heads),
                    k: gather_part(k, &seqs, 0, heads),
                    v: gather_part(v, &seqs, 0, heads),
                    seed: batch.seed,
                    slice_base: 0,
                    lens: Some(vec![valid]),
                    causal: batch.causal,
                    cache_quant: self.opts.cache_quant,
                    session: Some(ShardSession {
                        session: sref.cache.session,
                        generation: sref.cache.generation,
                        span_start: sref.span_start,
                    }),
                };
                // the ring owner or local — never another shard, so a
                // down owner can't scatter session state over the fleet
                let shard = self
                    .owner_index(sref.cache.session)
                    .filter(|&i| !self.down[i].load(Ordering::Relaxed));
                jobs.push(Job { seqs, head0: 0, nheads: heads, shard,
                                req, session_seq: Some(b) });
            }
        }

        // dispatch every job concurrently: shard latency overlaps, and
        // the gather/scatter copies stay on this thread's schedule
        let replies: Vec<ShardReply> = std::thread::scope(|scope| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|job| scope.spawn(move || self.run_job(job, ctx)))
                .collect();
            handles
                .into_iter()
                .zip(&jobs)
                .map(|(h, job)| {
                    // a panicked dispatch thread degrades to local
                    // compute — same bits, single-host speed — instead
                    // of cascading the panic through the gateway
                    h.join().unwrap_or_else(|_| {
                        self.solve_local(&job.req, ctx)
                    })
                })
                .collect()
        });

        for (job, rep) in jobs.iter().zip(&replies) {
            for (pos, &b) in job.seqs.iter().enumerate() {
                for hh in 0..job.nheads {
                    out.slice_mut(b * heads + job.head0 + hh)
                        .copy_from_slice(
                            rep.out.view(pos * job.nheads + hh).data);
                }
            }
            if let Some(b) = job.session_seq {
                outcomes[b] = rep.outcome.unwrap_or(SeqOutcome::Miss {
                    recomputed_rows: batch.valid_len(b),
                });
            }
        }
        (out, outcomes)
    }

    /// Dispatch one job: bounded retry with doubling backoff against
    /// its shard, then degraded-mode local fallback (marking the shard
    /// down).  A malformed reply counts as a failure — a shard can be
    /// wrong as well as unreachable.
    fn run_job(&self, job: &Job, ctx: &ExecCtx) -> ShardReply {
        if let Some(si) = job.shard {
            let want = (job.req.q.batch, job.req.q.heads,
                        job.req.q.rows, job.req.v.cols);
            let mut backoff = self.opts.backoff;
            for attempt in 0..=self.opts.retries {
                if attempt > 0 {
                    std::thread::sleep(backoff);
                    backoff *= 2;
                }
                match self.transports[si].execute(&job.req) {
                    Ok(rep) => {
                        let shape = (rep.out.batch, rep.out.heads,
                                     rep.out.rows, rep.out.cols);
                        let complete = shape == want
                            && (job.session_seq.is_none()
                                || rep.outcome.is_some());
                        if complete {
                            self.down[si].store(false, Ordering::Relaxed);
                            if let Some(c) = rep.cache {
                                *crate::exec::lock_unpoisoned(
                                    &self.stats[si]) = c;
                            }
                            return rep;
                        }
                        log::warn!("shard {} returned a malformed reply",
                                   self.ids[si]);
                    }
                    Err(e) => {
                        log::debug!("shard {} attempt {attempt}: {e:#}",
                                    self.ids[si]);
                    }
                }
            }
            log::warn!("shard {} failed {} attempts — marking it down, \
                        solving locally",
                       self.ids[si], self.opts.retries + 1);
            self.down[si].store(true, Ordering::Relaxed);
        }
        self.solve_local(&job.req, ctx)
    }

    /// Degraded-mode execution of one shard request on this host —
    /// plain parts run the offset solve, session steps run the local
    /// caching backend.  Same bits, single-host speed.
    fn solve_local(&self, req: &ShardRequest, ctx: &ExecCtx)
                   -> ShardReply {
        match req.session {
            None => {
                let mut b = AttnBatch::new(&req.q, &req.k, &req.v,
                                           req.seed)
                    .with_causal(req.causal);
                if let Some(lens) = req.lens.as_deref() {
                    b = b.with_lens(lens);
                }
                ShardReply {
                    out: solve_batch_offset(self.kernel.as_ref(), &b,
                                            req.slice_base, ctx),
                    outcome: None,
                    cache: None,
                }
            }
            Some(s) => {
                let sessions = [Some(SessionRef {
                    cache: CacheRef { session: s.session,
                                      generation: s.generation },
                    span_start: s.span_start,
                })];
                let lens = req.lens.clone()
                    .unwrap_or_else(|| vec![req.q.rows]);
                let b = AttnBatch::new(&req.q, &req.k, &req.v, req.seed)
                    .with_lens(&lens)
                    .with_sessions(&sessions)
                    .with_causal(req.causal);
                let (out, outcomes) =
                    self.local.execute_with_report(&b, ctx);
                // no stats snapshot: the local cache's counters are
                // read directly by cache_stats()
                ShardReply { out, outcome: Some(outcomes[0]),
                             cache: None }
            }
        }
    }
}

impl AttentionBackend for ShardedBackend {
    fn backend_name(&self) -> String {
        format!("sharded[{}]:{}", self.transports.len(), self.kernel_name)
    }

    fn execute(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx)
               -> BatchMatrix {
        self.execute_with_report(batch, ctx).0
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::attention::NativeBackend;
    use crate::prng::Xoshiro256;

    fn qkv(bsz: usize, h: usize, n: usize, d: usize, seed: u64)
           -> (BatchMatrix, BatchMatrix, BatchMatrix) {
        let mut rng = Xoshiro256::new(seed);
        (BatchMatrix::randn(bsz, h, n, d, &mut rng),
         BatchMatrix::randn(bsz, h, n, d, &mut rng),
         BatchMatrix::randn(bsz, h, n, d, &mut rng))
    }

    #[test]
    fn plan_parts_cover_every_slice_exactly_once() {
        for &(nseq, heads, shards) in &[(0usize, 2usize, 3usize),
                                        (1, 1, 1), (1, 4, 3), (2, 3, 8),
                                        (5, 2, 2), (7, 3, 4), (4, 4, 1),
                                        (3, 2, 16)] {
            let parts = plan_parts(nseq, heads, shards);
            assert!(parts.len() <= shards.max(1),
                    "({nseq},{heads},{shards}) made {} parts",
                    parts.len());
            let mut seen = vec![0usize; nseq * heads];
            for p in &parts {
                // multi-sequence parts must span every head, or their
                // slices are not contiguous and one slice_base cannot
                // key them
                if p.nseq > 1 {
                    assert_eq!((p.head0, p.nheads), (0, heads));
                }
                for s in p.seq0..p.seq0 + p.nseq {
                    for hh in p.head0..p.head0 + p.nheads {
                        seen[s * heads + hh] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1),
                    "({nseq},{heads},{shards}) coverage {seen:?}");
        }
    }

    #[test]
    fn solve_batch_offset_zero_is_solve_batch() {
        let (q, k, v) = qkv(2, 2, 16, 8, 3);
        let kernel = crate::attention::kernel_by_name("full").unwrap();
        let batch = AttnBatch::new(&q, &k, &v, 5);
        let ctx = ExecCtx::sequential();
        let a = solve_batch_offset(kernel.as_ref(), &batch, 0, &ctx);
        let b = kernel.solve_batch(&batch, &ctx);
        assert!(a.bit_identical(&b));
    }

    #[test]
    fn sharded_plain_batches_are_bit_identical_to_native() {
        let (q, k, v) = qkv(5, 2, 32, 8, 11);
        let lens = [32usize, 7, 19, 32, 1];
        let ctx = ExecCtx::sequential();
        for kernel in ["full", "i-clustered-4", "lsh-2"] {
            let native = NativeBackend::by_name(kernel).unwrap();
            for shards in [1usize, 2, 3] {
                let sharded =
                    ShardedBackend::in_process(kernel, shards, 1).unwrap();
                for masked in [false, true] {
                    let mut batch = AttnBatch::new(&q, &k, &v, 5);
                    if masked {
                        batch = batch.with_lens(&lens);
                    }
                    let got = sharded.execute(&batch, &ctx);
                    let want = native.execute(&batch, &ctx);
                    assert!(got.bit_identical(&want),
                            "{kernel} shards={shards} masked={masked}");
                }
            }
        }
    }

    #[test]
    fn single_sequence_batches_split_along_the_head_axis() {
        // b = 1 < 3 shards: the planner must go to per-head parts
        assert_eq!(plan_parts(1, 4, 3).len(), 3);
        let (q, k, v) = qkv(1, 4, 40, 8, 21);
        let lens = [23usize];
        let ctx = ExecCtx::sequential();
        let native = NativeBackend::by_name("oracle-top-8").unwrap();
        let sharded =
            ShardedBackend::in_process("oracle-top-8", 3, 1).unwrap();
        let batch = AttnBatch::new(&q, &k, &v, 9).with_lens(&lens);
        let got = sharded.execute(&batch, &ctx);
        let want = native.execute(&batch, &ctx);
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn mixed_plain_and_session_batches_match_the_single_host_cache() {
        let (q, k, v) = qkv(3, 2, 24, 8, 77);
        let sharded =
            ShardedBackend::in_process("i-clustered-4", 2, 1).unwrap();
        let reference = CachingBackend::native(
            "i-clustered-4", Arc::new(KvCache::unbounded())).unwrap();
        let ctx = ExecCtx::sequential();
        let sid = 41u64;
        // prefill (span 0 misses by contract), then two decode steps
        let steps = [(12usize, 0usize), (18, 12), (24, 18)];
        for (step, &(len, span)) in steps.iter().enumerate() {
            let lens = [20usize, len, 24];
            let sessions = [
                None,
                Some(SessionRef {
                    cache: CacheRef { session: sid, generation: 3 },
                    span_start: span,
                }),
                None,
            ];
            let batch = AttnBatch::new(&q, &k, &v, 9)
                .with_lens(&lens)
                .with_sessions(&sessions);
            let (got, got_oc) = sharded.execute_with_report(&batch, &ctx);
            let (want, want_oc) =
                reference.execute_with_report(&batch, &ctx);
            assert!(got.bit_identical(&want), "step {step} diverged");
            assert_eq!(got_oc, want_oc, "step {step} outcomes diverged");
            if step > 0 {
                assert!(matches!(got_oc[1], SeqOutcome::Hit { .. }),
                        "step {step} should hit the owning shard's cache");
            }
        }
    }

    #[test]
    fn quantized_sharded_sessions_match_the_single_host_quant_cache() {
        // quantization is deterministic, so routing a quantized session
        // through the fleet must reproduce the single-host quantized
        // CachingBackend bit for bit — the sharded twin of the cache's
        // own tolerance contract
        let (q, k, v) = qkv(3, 2, 24, 8, 55);
        for quant in [CacheQuant::I8PerHead, CacheQuant::I8PerPanel] {
            for shards in [1usize, 3] {
                let opts = ShardOptions { cache_quant: quant,
                                          ..ShardOptions::default() };
                let sharded = ShardedBackend::in_process_with(
                    "i-clustered-4", shards, 1, opts).unwrap();
                let reference = CachingBackend::native(
                    "i-clustered-4",
                    Arc::new(KvCache::new(KvCacheOptions {
                        quant,
                        ..KvCacheOptions::default()
                    }))).unwrap();
                let ctx = ExecCtx::sequential();
                let sid = 47u64;
                let steps = [(12usize, 0usize), (18, 12), (24, 18)];
                for (step, &(len, span)) in steps.iter().enumerate() {
                    let lens = [20usize, len, 24];
                    let sessions = [
                        None,
                        Some(SessionRef {
                            cache: CacheRef { session: sid,
                                              generation: 2 },
                            span_start: span,
                        }),
                        None,
                    ];
                    let batch = AttnBatch::new(&q, &k, &v, 9)
                        .with_lens(&lens)
                        .with_sessions(&sessions);
                    let (got, got_oc) =
                        sharded.execute_with_report(&batch, &ctx);
                    let (want, want_oc) =
                        reference.execute_with_report(&batch, &ctx);
                    assert!(got.bit_identical(&want),
                            "{} shards={shards} step {step} diverged",
                            quant.name());
                    assert_eq!(got_oc, want_oc,
                               "{} shards={shards} step {step} outcomes",
                               quant.name());
                }
                // satellite telemetry: the owning shard's counter
                // snapshots rode the session replies back and
                // aggregate fleet-wide (one miss at prefill, hits on
                // the two decode steps that reused cached prefixes)
                let stats = sharded.cache_stats();
                assert!(stats.misses >= 1,
                        "{} shards={shards}: {stats:?}", quant.name());
                assert!(stats.hits >= 2,
                        "{} shards={shards}: {stats:?}", quant.name());
                assert!(stats.saved_rows >= 12 + 18,
                        "{} shards={shards}: {stats:?}", quant.name());
            }
        }
    }

    #[test]
    fn causal_linear_sessions_match_the_single_host_cache() {
        // the recurrent decode state rides the same consistent-hash
        // session placement as KV panels: a sharded causal linear
        // session is bit-identical to the single-host caching backend
        let (q, k, v) = qkv(3, 2, 24, 8, 99);
        for shards in [1usize, 3] {
            let sharded =
                ShardedBackend::in_process("linear", shards, 1).unwrap();
            let reference = CachingBackend::native(
                "linear", Arc::new(KvCache::unbounded())).unwrap();
            let ctx = ExecCtx::sequential();
            let sid = 43u64;
            let steps = [(12usize, 0usize), (18, 12), (24, 18)];
            for (step, &(len, span)) in steps.iter().enumerate() {
                let lens = [20usize, len, 24];
                let sessions = [
                    None,
                    Some(SessionRef {
                        cache: CacheRef { session: sid, generation: 1 },
                        span_start: span,
                    }),
                    None,
                ];
                let batch = AttnBatch::new(&q, &k, &v, 9)
                    .with_lens(&lens)
                    .with_sessions(&sessions)
                    .with_causal(true);
                let (got, got_oc) =
                    sharded.execute_with_report(&batch, &ctx);
                let (want, want_oc) =
                    reference.execute_with_report(&batch, &ctx);
                assert!(got.bit_identical(&want),
                        "shards={shards} step {step} diverged");
                assert_eq!(got_oc, want_oc,
                           "shards={shards} step {step} outcomes");
                if step > 0 {
                    assert!(matches!(got_oc[1],
                                     SeqOutcome::Hit { computed_rows,
                                                       .. }
                                     if computed_rows == len - span),
                            "shards={shards} step {step} should hit the \
                             owner's recurrent state");
                }
            }
        }
    }

    #[test]
    fn end_session_releases_the_owning_shards_cache() {
        let engines: Vec<Arc<ShardEngine>> =
            (0..2).map(|_| Arc::new(ShardEngine::new(1))).collect();
        let transports: Vec<Box<dyn ShardTransport>> = engines
            .iter()
            .enumerate()
            .map(|(i, e)| {
                Box::new(InProcessShard::new(&format!("local-{i}"),
                                             e.clone()))
                    as Box<dyn ShardTransport>
            })
            .collect();
        let sharded = ShardedBackend::from_transports(
            "full", transports, ShardOptions::default()).unwrap();
        let (q, k, v) = qkv(1, 2, 16, 4, 31);
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: 5, generation: 0 },
            span_start: 0,
        })];
        let batch =
            AttnBatch::new(&q, &k, &v, 1).with_sessions(&sessions);
        let _ = sharded.execute(&batch, &ExecCtx::sequential());
        let cached_rows = || {
            engines.iter().map(|e| e.cache().used_rows()).sum::<usize>()
        };
        assert!(cached_rows() > 0, "prefill should populate one shard");
        sharded.end_session(5);
        assert_eq!(cached_rows(), 0,
                   "end_session must reach the owning shard");
    }

    struct FailingShard {
        id: String,
    }

    impl ShardTransport for FailingShard {
        fn shard_id(&self) -> String {
            self.id.clone()
        }

        fn execute(&self, _req: &ShardRequest) -> Result<ShardReply> {
            Err(anyhow!("injected failure"))
        }

        fn ping(&self) -> bool {
            false
        }

        fn end_session(&self, _session: u64) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn a_down_shard_degrades_to_local_compute_without_changing_bits() {
        let transports: Vec<Box<dyn ShardTransport>> = vec![
            Box::new(InProcessShard::new("up-0",
                                         Arc::new(ShardEngine::new(1)))),
            Box::new(FailingShard { id: "down-1".into() }),
        ];
        let opts = ShardOptions { retries: 1,
                                  backoff: Duration::from_millis(1),
                                  vnodes: 16 };
        let sharded =
            ShardedBackend::from_transports("full", transports, opts)
                .unwrap();
        let (q, k, v) = qkv(4, 2, 24, 8, 41);
        let batch = AttnBatch::new(&q, &k, &v, 13);
        let ctx = ExecCtx::sequential();
        let want = NativeBackend::by_name("full").unwrap()
            .execute(&batch, &ctx);
        // first flush: the failing shard's part falls back locally
        let got = sharded.execute(&batch, &ctx);
        assert!(got.bit_identical(&want),
                "degraded flush changed the bits");
        assert_eq!(sharded.health_check(), vec![true, false]);
        // later flushes plan around the down shard — still identical
        let got2 = sharded.execute(&batch, &ctx);
        assert!(got2.bit_identical(&want));
    }

    #[test]
    fn f32_frames_round_trip_little_endian() {
        let xs = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 3.25e7, -0.0,
                      f32::MAX];
        let mut buf = Vec::new();
        write_f32s(&mut buf, &xs).unwrap();
        assert_eq!(buf.len(), xs.len() * 4);
        let got =
            read_f32s(&mut std::io::Cursor::new(buf), xs.len()).unwrap();
        assert_eq!(got.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
                   xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>());
        // frames longer than the streaming chunk arrive intact and in
        // order (the pipelined writer splits them into several writes)
        let mut rng = Xoshiro256::new(17);
        let big = crate::tensor::Matrix::randn(300, 100, &mut rng).data;
        assert!(big.len() > 3 * 8192, "must span several chunks");
        let mut buf = Vec::new();
        write_f32s(&mut buf, &big).unwrap();
        assert_eq!(buf.len(), big.len() * 4);
        let got =
            read_f32s(&mut std::io::Cursor::new(buf), big.len()).unwrap();
        assert!(got.iter().zip(&big).all(|(a, b)| a.to_bits()
                                         == b.to_bits()));
        // the empty frame writes nothing
        let mut buf = Vec::new();
        write_f32s(&mut buf, &[]).unwrap();
        assert!(buf.is_empty());
    }

    #[test]
    fn solve_headers_round_trip_with_full_u64_precision() {
        let (q, k, v) = qkv(1, 2, 4, 3, 1);
        let req = ShardRequest {
            kernel: "full".into(),
            q,
            k,
            v,
            // any of these would round if encoded as a JSON f64
            seed: u64::MAX - 12,
            slice_base: (1u64 << 60) | 7,
            lens: Some(vec![3]),
            causal: true,
            cache_quant: CacheQuant::I8PerPanel,
            session: Some(ShardSession {
                session: (1u64 << 63) | 5,
                generation: u64::MAX,
                span_start: 2,
            }),
        };
        let line = solve_header(9, &req).to_string();
        let hdr = SolveHeader::parse(&parse(&line).unwrap()).unwrap();
        assert_eq!(hdr.id, 9);
        assert_eq!(hdr.kernel, "full");
        assert_eq!(hdr.seed, u64::MAX - 12);
        assert_eq!(hdr.slice_base, (1u64 << 60) | 7);
        assert_eq!(hdr.lens.as_deref(), Some(&[3usize][..]));
        assert!(hdr.causal);
        assert_eq!(hdr.cache_quant, CacheQuant::I8PerPanel);
        let s = hdr.session.unwrap();
        assert_eq!((s.session, s.generation, s.span_start),
                   ((1u64 << 63) | 5, u64::MAX, 2));
        assert_eq!((hdr.batch, hdr.heads, hdr.rows, hdr.dk, hdr.dv),
                   (1, 2, 4, 3, 3));
        // a causal-less header (pre-causal peer) parses as false
        let legacy = line.replace("\"causal\":true,", "");
        let hdr2 = SolveHeader::parse(&parse(&legacy).unwrap()).unwrap();
        assert!(!hdr2.causal);
        // and a quant-less header (pre-quantization peer) parses as off
        let legacy =
            line.replace("\"cache_quant\":\"i8-panel\",", "");
        let hdr3 = SolveHeader::parse(&parse(&legacy).unwrap()).unwrap();
        assert_eq!(hdr3.cache_quant, CacheQuant::Off);
        // an unknown declared mode is an error, not a silent default
        let bad = line.replace("\"cache_quant\":\"i8-panel\"",
                               "\"cache_quant\":\"fp4\"");
        assert!(SolveHeader::parse(&parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn default_headers_stay_byte_stable_without_quant_fields() {
        // the wire-stability contract: a request under the default
        // policy must not mention cache_quant at all
        let (q, k, v) = qkv(1, 1, 4, 3, 2);
        let req = ShardRequest {
            kernel: "full".into(),
            q,
            k,
            v,
            seed: 7,
            slice_base: 0,
            lens: None,
            causal: false,
            cache_quant: CacheQuant::Off,
            session: None,
        };
        let line = solve_header(1, &req).to_string();
        assert!(!line.contains("cache_quant"), "leaked field: {line}");
    }

    #[test]
    fn cache_stats_round_trip_and_parse_leniently() {
        let stats = ShardCacheStats { hits: 12, misses: 3,
                                      saved_rows: 480 };
        let v =
            parse(&cache_stats_to_value(&stats).to_string()).unwrap();
        assert_eq!(cache_stats_from_value(&v), stats);
        // lenient: a reply from a worker that predates some counter
        // reads as zero for that counter, never as an error
        let sparse = parse("{\"hits\": 2}").unwrap();
        assert_eq!(cache_stats_from_value(&sparse),
                   ShardCacheStats { hits: 2, misses: 0,
                                     saved_rows: 0 });
    }

    #[test]
    fn outcomes_round_trip_through_json() {
        for oc in [SeqOutcome::Bypass,
                   SeqOutcome::Hit { reused_rows: 7, computed_rows: 9,
                                     reclustered: true },
                   SeqOutcome::Miss { recomputed_rows: 31 }] {
            let v = parse(&outcome_to_value(&oc).to_string()).unwrap();
            assert_eq!(outcome_from_value(&v).unwrap(), oc);
        }
    }

    #[test]
    fn engine_rejects_malformed_requests_instead_of_panicking() {
        let engine = ShardEngine::new(1);
        let (q, k, v) = qkv(2, 1, 8, 4, 2);
        let base = |session| ShardRequest {
            kernel: "full".into(),
            q: q.clone(),
            k: k.clone(),
            v: v.clone(),
            seed: 0,
            slice_base: 0,
            lens: None,
            causal: false,
            cache_quant: CacheQuant::Off,
            session,
        };
        assert!(engine.solve(&ShardRequest {
            kernel: "no-such-kernel".into(),
            ..base(None)
        }).is_err());
        assert!(engine.solve(&ShardRequest {
            lens: Some(vec![4]), // one entry for a 2-sequence batch
            ..base(None)
        }).is_err());
        assert!(engine.solve(&ShardRequest {
            lens: Some(vec![4, 99]), // out of 1..=rows
            ..base(None)
        }).is_err());
        // session requests must be single-sequence
        assert!(engine
            .solve(&base(Some(ShardSession { session: 1, generation: 0,
                                             span_start: 0 })))
            .is_err());
        // causal on a non-supporting kernel is an error reply, not the
        // kernel's panic
        assert!(engine.solve(&ShardRequest {
            causal: true,
            ..base(None)
        }).is_err());
        // and a well-formed request still solves
        assert!(engine.solve(&base(None)).is_ok());
    }
}
