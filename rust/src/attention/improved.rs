//! ct-contract: bit-exact
//!
//! Improved clustered attention (paper eqs. 9–11 / suppl. 15–17): each
//! cluster keeps exact attention on its top-k keys and falls back to the
//! centroid approximation on the complement.
//!
//! Compute shape after the tiled-core rewrite:
//!  - A^c and the full centroid values `A^c·V` come from the blocked
//!    GEMM core (row-partitioned over the ctx pool);
//!  - the complement basis V̂^b is `A^c·V` minus the top-k
//!    contributions — no per-cluster O(N·Dv) rescan and no per-cluster
//!    scratch allocation (the seed allocated an accumulator per
//!    cluster);
//!  - the per-query top-k pass partitions over **output rows**, one
//!    reused `dots` scratch per worker chunk; the softmax reduction of
//!    a row never crosses a worker boundary, so parallel output is
//!    bit-identical to sequential.

use crate::clustering::Clustering;
use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, gemm, softmax_inplace, topk_indices, Matrix};

use super::clustered::clustered_attention_matrix_ctx;
use super::{AttentionKernel, AttnProblem, Cost};

pub fn improved_clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                                    cl: &Clustering, topk: usize) -> Matrix {
    improved_clustered_attention_ctx(q, k, v, cl, topk,
                                     &ExecCtx::sequential())
}

/// [`improved_clustered_attention`] over the ctx pool.
pub fn improved_clustered_attention_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                                        cl: &Clustering, topk: usize,
                                        ctx: &ExecCtx) -> Matrix {
    let n = q.rows;
    let c = cl.n_clusters;
    let dv = v.cols;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix_ctx(q, k, cl, ctx); // (C, N)
    let v_full = gemm::matmul_nn(&a_c, v, ctx); // (C, Dv): Σ_all w·V

    // per-cluster top-k keys and captured mass m̂ (eq. 9)
    let top: Vec<Vec<usize>> =
        ctx.map_indexed(c, |j| topk_indices(a_c.row(j), topk));
    // V̂^b basis (eq. 17): full centroid values minus the top-k terms —
    // written straight into the row, no per-cluster accumulator
    let mut mhat = vec![0f32; c];
    let mut v_b = Matrix::zeros(c, dv);
    for j in 0..c {
        let idx = &top[j];
        // ct-lint: allow(det-float-reduce, reason = "ordered sum over the top-k index list produced by topk_indices; reduction order is fixed")
        mhat[j] = idx.iter().map(|&l| a_c.at(j, l)).sum();
        let row = v_b.row_mut(j);
        row.copy_from_slice(v_full.row(j));
        for &l in idx {
            axpy(row, -a_c.at(j, l), v.row(l));
        }
    }

    // V̂ = V̂^t + V̂^b (eqs. 15–16), partitioned over output rows
    let mut out = Matrix::zeros(n, dv);
    par_rows(ctx, &mut out.data, n, dv, |range, chunk| {
        let mut dots = vec![0f32; topk]; // one scratch per worker chunk
        for (off, i) in range.enumerate() {
            let j = cl.groups[i] as usize;
            let idx = &top[j];
            let t = idx.len();
            for (slot, &key_idx) in idx.iter().enumerate() {
                dots[slot] = dot(q.row(i), k.row(key_idx)) * scale;
            }
            softmax_inplace(&mut dots[..t]);
            let orow = &mut chunk[off * dv..(off + 1) * dv];
            orow.copy_from_slice(v_b.row(j));
            for (slot, &key_idx) in idx.iter().enumerate() {
                axpy(orow, dots[slot] * mhat[j], v.row(key_idx));
            }
        }
    });
    out
}

/// Dense A^t (eq. 10) for fig. 8.
pub fn improved_clustered_attention_matrix(q: &Matrix, k: &Matrix,
                                           cl: &Clustering, topk: usize)
                                           -> Matrix {
    let n = q.rows;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix_ctx(q, k, cl,
                                             &ExecCtx::sequential());
    let mut out = Matrix::zeros(n, n);
    let mut dots = vec![0f32; topk];
    for i in 0..n {
        let j = cl.groups[i] as usize;
        let idx = topk_indices(a_c.row(j), topk);
        // ct-lint: allow(det-float-reduce, reason = "ordered sum over the top-k index list produced by topk_indices; reduction order is fixed")
        let mhat: f32 = idx.iter().map(|&l| a_c.at(j, l)).sum();
        out.row_mut(i).copy_from_slice(a_c.row(j));
        for (slot, &l) in idx.iter().enumerate() {
            dots[slot] = dot(q.row(i), k.row(l)) * scale;
        }
        softmax_inplace(&mut dots[..idx.len()]);
        for (slot, &l) in idx.iter().enumerate() {
            out.set(i, l, dots[slot] * mhat);
        }
    }
    out
}

/// Improved clustered attention kernel (clustered + exact top-k keys).
#[derive(Debug, Clone, Copy)]
pub struct ImprovedClusteredAttention {
    pub clusters: usize,
    pub bits: usize,
    pub iters: usize,
    pub topk: usize,
}

impl AttentionKernel for ImprovedClusteredAttention {
    fn name(&self) -> String {
        format!("i-clustered-{}", self.clusters)
    }

    /// Masking = solving the valid-prefix sub-problem: clustering sees
    /// only valid queries, `A^c` has only valid key columns, so the
    /// per-cluster top-k can never select a padded key and the masked
    /// run is bit-identical to the unpadded run.
    ///
    /// A `query_span` is honored by computing the full valid solve and
    /// emitting only the span rows (exact by construction): this
    /// kernel's rows couple through the shared (C × N) matrix and the
    /// per-cluster top-k basis, so an affected-cluster pruning is left
    /// to the KV-cached reuse path (`attention::cache`), which freezes
    /// that shared state between re-clusters.
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal,
                "i-clustered does not support causal attention");
        let (q, k, v) = p.valid_qkv();
        let cl = crate::clustering::cluster_queries_ctx(
            &q, self.clusters, self.bits, self.iters, rng, ctx);
        let out =
            improved_clustered_attention_ctx(&q, &k, &v, &cl, self.topk,
                                             ctx);
        if p.is_spanned() {
            return p.restore_span(out.row_span(p.span_start(), out.rows));
        }
        p.restore_rows(out)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (c, b, l) = (self.clusters as u64, self.bits as u64,
                         self.iters as u64);
        Cost {
            // clustering + A^c + A^c·V + per-query top-k refinement
            flops: n64 * dk64 * b + n64 * c * l
                + c * n64 * (dk64 + dv64)
                + n64 * (self.topk as u64) * (dk64 + dv64),
            // this kernel genuinely materialises the (C × N) matrix,
            // plus codes and the top-k working set
            bytes: 4 * c * n64 + n64 * b / 8
                + 4 * n64 * (self.topk as u64),
        }
    }
}
