//! Improved clustered attention (paper eqs. 9–11 / suppl. 15–17): each
//! cluster keeps exact attention on its top-k keys and falls back to the
//! centroid approximation on the complement.
//!
//! The complement pass uses a boolean top-k membership mask per cluster,
//! so each row is a single O(N) sweep — the paper's stated complexity —
//! instead of the O(N·topk) `contains` rescan the seed shipped with.

use crate::clustering::Clustering;
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, dot, softmax_inplace, topk_indices, Matrix};

use super::clustered::{clustered_attention_matrix, ClusteredAttention};
use super::{AttentionKernel, Cost};

pub fn improved_clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                                    cl: &Clustering, topk: usize) -> Matrix {
    let n = q.rows;
    let c = cl.n_clusters;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix(q, k, cl); // (C, N)

    // per-cluster top-k keys, captured mass m̂ (eq. 9) and V̂^b basis
    let mut top: Vec<Vec<usize>> = Vec::with_capacity(c);
    let mut mhat = vec![0f32; c];
    let mut v_b = Matrix::zeros(c, v.cols); // complement average per cluster
    // boolean membership mask, reset between clusters: keeps the
    // complement pass O(N) total per cluster (eq. 17)
    let mut in_top = vec![false; k.rows];
    for j in 0..c {
        let idx = topk_indices(a_c.row(j), topk);
        mhat[j] = idx.iter().map(|&i| a_c.at(j, i)).sum();
        for &key_idx in &idx {
            in_top[key_idx] = true;
        }
        // V̂^b row: clustered attention with top-k columns zeroed (eq. 17)
        let row = a_c.row(j);
        let mut acc = vec![0f32; v.cols];
        for (key_idx, &w) in row.iter().enumerate() {
            if w != 0.0 && !in_top[key_idx] {
                axpy(&mut acc, w, v.row(key_idx));
            }
        }
        for &key_idx in &idx {
            in_top[key_idx] = false;
        }
        v_b.row_mut(j).copy_from_slice(&acc);
        top.push(idx);
    }

    // V̂ = V̂^t + V̂^b (eqs. 15–16)
    let mut out = Matrix::zeros(n, v.cols);
    let mut dots = vec![0f32; topk];
    for i in 0..n {
        let j = cl.groups[i] as usize;
        let idx = &top[j];
        let t = idx.len();
        for (slot, &key_idx) in idx.iter().enumerate() {
            dots[slot] = dot(q.row(i), k.row(key_idx)) * scale;
        }
        softmax_inplace(&mut dots[..t]);
        let orow = out.row_mut(i);
        orow.copy_from_slice(v_b.row(j));
        for (slot, &key_idx) in idx.iter().enumerate() {
            axpy(orow, dots[slot] * mhat[j], v.row(key_idx));
        }
    }
    out
}

/// Dense A^t (eq. 10) for fig. 8.
pub fn improved_clustered_attention_matrix(q: &Matrix, k: &Matrix,
                                           cl: &Clustering, topk: usize)
                                           -> Matrix {
    let n = q.rows;
    let scale = 1.0 / (q.cols as f32).sqrt();
    let a_c = clustered_attention_matrix(q, k, cl);
    let mut out = Matrix::zeros(n, n);
    let mut dots = vec![0f32; topk];
    for i in 0..n {
        let j = cl.groups[i] as usize;
        let idx = topk_indices(a_c.row(j), topk);
        let mhat: f32 = idx.iter().map(|&l| a_c.at(j, l)).sum();
        out.row_mut(i).copy_from_slice(a_c.row(j));
        for (slot, &l) in idx.iter().enumerate() {
            dots[slot] = dot(q.row(i), k.row(l)) * scale;
        }
        softmax_inplace(&mut dots[..idx.len()]);
        for (slot, &l) in idx.iter().enumerate() {
            out.set(i, l, dots[slot] * mhat);
        }
    }
    out
}

/// Improved clustered attention kernel (clustered + exact top-k keys).
#[derive(Debug, Clone, Copy)]
pub struct ImprovedClusteredAttention {
    pub clusters: usize,
    pub bits: usize,
    pub iters: usize,
    pub topk: usize,
}

impl AttentionKernel for ImprovedClusteredAttention {
    fn name(&self) -> String {
        format!("i-clustered-{}", self.clusters)
    }

    fn run(&self, q: &Matrix, k: &Matrix, v: &Matrix,
           rng: &mut Xoshiro256) -> Matrix {
        let cl = crate::clustering::cluster_queries(
            q, self.clusters, self.bits, self.iters, rng);
        improved_clustered_attention(q, k, v, &cl, self.topk)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let base = ClusteredAttention {
            clusters: self.clusters,
            bits: self.bits,
            iters: self.iters,
        }
        .cost(n, dk, dv);
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        Cost {
            flops: base.flops + n64 * (self.topk as u64) * (dk64 + dv64),
            bytes: base.bytes + 4 * n64 * (self.topk as u64),
        }
    }
}
