//! ct-contract: bit-exact
//!
//! The attention backend seam: anything that can execute an
//! [`AttnBatch`] descriptor.
//!
//! Two execution stacks serve attention today and both consume the same
//! request information — a ragged (B, H, N, D) batch plus per-sequence
//! valid lengths:
//!
//! - **native** ([`NativeBackend`]): a registry [`AttentionKernel`]
//!   solving descriptors over the exec pool (the `ServingGateway`
//!   path).  Valid-length masking happens in `solve_batch`, so padded
//!   rows are never computed.
//! - **compiled HLO** (`coordinator::InferenceEngine`): forward
//!   programs that take the lengths as their `xlen` input and mask
//!   inside the graph.  A raw-attention HLO executable wrapped in this
//!   trait is the drop-in second implementation once such a program is
//!   lowered.
//!
//! [`AttentionBackend`] is deliberately tiny — one execute method over
//! the descriptor — because the descriptor is where options grow.
//! Cross-request KV caching landed exactly this way: cache handles
//! ride the descriptor (`AttnBatch::sessions`) and
//! [`super::CachingBackend`] wraps any implementation of this trait
//! without touching a kernel signature.  Multi-host fan-out landed the
//! same way: [`super::ShardedBackend`] splits the descriptor across
//! shard workers and implements this trait bit-identically to the
//! native engine (see [`super::sharded`]).

use crate::exec::ExecCtx;
use crate::tensor::batch::BatchMatrix;

use super::problem::AttnBatch;
use super::{kernel_by_name, AttentionKernel};

/// One attention execution engine, addressed by descriptor.
///
/// Implementations must uphold the engine contracts: output slice `s`
/// is a pure function of `(inputs[s], seed, s)` (so results are
/// independent of `ctx` worker placement), and masked sequences obey
/// the valid-length contract (`AttnProblem` docs) — rows `lens[b]..`
/// of every output slice are zero and the valid rows match the
/// unpadded computation.
pub trait AttentionBackend: Send + Sync {
    /// Identity for logs and reports, e.g. `"native:i-clustered-8"`.
    fn backend_name(&self) -> String;

    /// Execute one (possibly ragged) batch descriptor.
    fn execute(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx) -> BatchMatrix;
}

/// The native execution engine: a registry kernel solving descriptors
/// on the caller's [`ExecCtx`].
///
/// ```
/// use clustered_transformers::attention::{AttnBatch, AttentionBackend,
///                                         NativeBackend};
/// use clustered_transformers::exec::ExecCtx;
/// use clustered_transformers::prng::Xoshiro256;
/// use clustered_transformers::tensor::batch::BatchMatrix;
///
/// let backend = NativeBackend::by_name("full").unwrap();
/// assert_eq!(backend.backend_name(), "native:full");
/// let mut rng = Xoshiro256::new(0);
/// let q = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
/// let k = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
/// let v = BatchMatrix::randn(1, 2, 8, 4, &mut rng);
/// let lens = [5usize]; // rows 5.. of the one sequence are padding
/// let out = backend.execute(
///     &AttnBatch::new(&q, &k, &v, 0).with_lens(&lens),
///     &ExecCtx::sequential());
/// assert_eq!((out.batch, out.heads, out.rows, out.cols), (1, 2, 8, 4));
/// ```
pub struct NativeBackend {
    kernel: Box<dyn AttentionKernel>,
}

impl NativeBackend {
    pub fn new(kernel: Box<dyn AttentionKernel>) -> Self {
        Self { kernel }
    }

    /// Resolve a kernel by registry name (`None` for unknown names —
    /// the same validation surface `kernel_by_name` gives).
    pub fn by_name(name: &str) -> Option<Self> {
        kernel_by_name(name).map(Self::new)
    }

    pub fn kernel(&self) -> &dyn AttentionKernel {
        self.kernel.as_ref()
    }
}

impl AttentionBackend for NativeBackend {
    fn backend_name(&self) -> String {
        format!("native:{}", self.kernel.name())
    }

    fn execute(&self, batch: &AttnBatch<'_>, ctx: &ExecCtx) -> BatchMatrix {
        self.kernel.solve_batch(batch, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::solve_batch_seq;
    use crate::exec::WorkerPool;
    use crate::prng::Xoshiro256;

    #[test]
    fn native_backend_resolves_names_like_the_registry() {
        assert!(NativeBackend::by_name("i-clustered-4").is_some());
        assert!(NativeBackend::by_name("no-such-kernel").is_none());
        let b = NativeBackend::by_name("clustered-4").unwrap();
        assert_eq!(b.backend_name(), "native:clustered-4");
        assert_eq!(b.kernel().name(), "clustered-4");
    }

    #[test]
    fn native_backend_execute_is_solve_batch_bit_for_bit() {
        let mut rng = Xoshiro256::new(3);
        let q = BatchMatrix::randn(2, 2, 16, 8, &mut rng);
        let k = BatchMatrix::randn(2, 2, 16, 8, &mut rng);
        let v = BatchMatrix::randn(2, 2, 16, 8, &mut rng);
        let lens = [9usize, 16];
        let backend = NativeBackend::by_name("i-clustered-4").unwrap();
        let batch = AttnBatch::new(&q, &k, &v, 11).with_lens(&lens);
        let got = backend.execute(
            &batch, &ExecCtx::with_par_rows(WorkerPool::new(3), 1));
        let want = solve_batch_seq(backend.kernel(), &batch);
        assert!(got.bit_identical(&want));
    }
}
