//! Clustered attention (paper eqs. 3–6): queries are grouped by the LSH +
//! Hamming-K-Means substrate, each cluster attends once through its
//! centroid, and members copy the centroid's result — O(N·C·D).

use crate::clustering::{self, Clustering};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, Matrix};

use super::{AttentionKernel, Cost};

/// Eq. (3): centroids of the member queries.
pub fn centroids(q: &Matrix, cl: &Clustering) -> Matrix {
    let mut cent = Matrix::zeros(cl.n_clusters, q.cols);
    for i in 0..q.rows {
        axpy(cent.row_mut(cl.groups[i] as usize), 1.0, q.row(i));
    }
    for c in 0..cl.n_clusters {
        if cl.counts[c] > 0 {
            let inv = 1.0 / cl.counts[c] as f32;
            for val in cent.row_mut(c) {
                *val *= inv;
            }
        }
    }
    cent
}

/// Eq. (4): A^c = softmax(Q^c K^T / sqrt(Dk)) — (C × N).
pub fn clustered_attention_matrix(q: &Matrix, k: &Matrix, cl: &Clustering)
                                  -> Matrix {
    let cent = centroids(q, cl);
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut a_c = cent.matmul_nt(k);
    a_c.scale(scale);
    a_c.softmax_rows();
    a_c
}

/// Eqs. (4)–(6): O(N·C·D).
pub fn clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                           cl: &Clustering) -> Matrix {
    let a_c = clustered_attention_matrix(q, k, cl);
    let v_c = a_c.matmul(v); // (C, Dv)
    let mut out = Matrix::zeros(q.rows, v.cols);
    for i in 0..q.rows {
        out.row_mut(i).copy_from_slice(v_c.row(cl.groups[i] as usize));
    }
    out
}

/// Clustered attention kernel: LSH → Hamming K-Means → centroid attention.
#[derive(Debug, Clone, Copy)]
pub struct ClusteredAttention {
    pub clusters: usize,
    pub bits: usize,
    pub iters: usize,
}

impl AttentionKernel for ClusteredAttention {
    fn name(&self) -> String {
        format!("clustered-{}", self.clusters)
    }

    fn run(&self, q: &Matrix, k: &Matrix, v: &Matrix,
           rng: &mut Xoshiro256) -> Matrix {
        let cl = clustering::cluster_queries(q, self.clusters, self.bits,
                                             self.iters, rng);
        clustered_attention(q, k, v, &cl)
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (c, b, l) = (self.clusters as u64, self.bits as u64,
                         self.iters as u64);
        Cost {
            // LSH + Lloyd (O(NCL + ND_kB)) + centroid attention
            flops: n64 * dk64 * b + n64 * c * l
                + c * n64 * (dk64 + dv64),
            bytes: 4 * c * n64 + n64 * b / 8,
        }
    }
}
