//! ct-contract: bit-exact
//!
//! Clustered attention (paper eqs. 3–6): queries are grouped by the LSH +
//! Hamming-K-Means substrate, each cluster attends once through its
//! centroid, and members copy the centroid's result — O(N·C·D).
//!
//! The centroid pass streams: the (C × N) attention matrix is only
//! materialised by [`clustered_attention_matrix`] (which the improved
//! kernel and fig. 8 genuinely need); the value path
//! [`clustered_attention`] runs the centroids through the streaming
//! softmax core, so its extra memory is O(N·block) like full attention.

use crate::clustering::{self, Clustering};
use crate::exec::{par_rows, ExecCtx};
use crate::prng::Xoshiro256;
use crate::tensor::{axpy, gemm, softmax_inplace, Matrix};

use super::full::streaming_softmax_attention;
use super::{AttentionKernel, AttnProblem, Cost};

/// Eq. (3): centroids of the member queries.
pub fn centroids(q: &Matrix, cl: &Clustering) -> Matrix {
    let mut cent = Matrix::zeros(cl.n_clusters, q.cols);
    for i in 0..q.rows {
        axpy(cent.row_mut(cl.groups[i] as usize), 1.0, q.row(i));
    }
    for c in 0..cl.n_clusters {
        if cl.counts[c] > 0 {
            let inv = 1.0 / cl.counts[c] as f32;
            for val in cent.row_mut(c) {
                *val *= inv;
            }
        }
    }
    cent
}

/// Eq. (4): A^c = softmax(Q^c K^T / sqrt(Dk)) — (C × N).
pub fn clustered_attention_matrix(q: &Matrix, k: &Matrix, cl: &Clustering)
                                  -> Matrix {
    clustered_attention_matrix_ctx(q, k, cl, &ExecCtx::sequential())
}

/// [`clustered_attention_matrix`] with the logits GEMM and the row
/// softmax partitioned over the ctx pool (centroid rows only — the
/// matrix stays O(C·N), which is what the improved kernel needs).
pub fn clustered_attention_matrix_ctx(q: &Matrix, k: &Matrix,
                                      cl: &Clustering, ctx: &ExecCtx)
                                      -> Matrix {
    let cent = centroids(q, cl);
    let scale = 1.0 / (q.cols as f32).sqrt();
    let mut a_c = gemm::matmul_nt(&cent, k, ctx);
    let cols = a_c.cols;
    par_rows(ctx, &mut a_c.data, cl.n_clusters, cols, |range, chunk| {
        for off in 0..range.len() {
            let row = &mut chunk[off * cols..(off + 1) * cols];
            for x in row.iter_mut() {
                *x *= scale;
            }
            softmax_inplace(row);
        }
    });
    a_c
}

/// Eqs. (4)–(6): O(N·C·D), streaming — the (C × N) matrix is never
/// materialised on this path.
pub fn clustered_attention(q: &Matrix, k: &Matrix, v: &Matrix,
                           cl: &Clustering) -> Matrix {
    clustered_attention_ctx(q, k, v, cl, &ExecCtx::sequential())
}

/// [`clustered_attention`] over the ctx pool.
pub fn clustered_attention_ctx(q: &Matrix, k: &Matrix, v: &Matrix,
                               cl: &Clustering, ctx: &ExecCtx) -> Matrix {
    let cent = centroids(q, cl);
    let scale = 1.0 / (q.cols as f32).sqrt();
    // centroid rows stream through the online-softmax core: O(N·block)
    let v_c = streaming_softmax_attention(&cent, k, v, scale, ctx);
    // member scatter is a pure row memcpy — forking scoped workers
    // would cost more than the copy, so it stays inline
    let mut out = Matrix::zeros(q.rows, v.cols);
    for i in 0..q.rows {
        out.row_mut(i).copy_from_slice(v_c.row(cl.groups[i] as usize));
    }
    out
}

/// Attend a query span through *only the clusters it touches*: centroid
/// attention rows are computed for the distinct clusters of
/// `groups_span` (each row of `cent` is one cluster's centroid) and
/// scattered back to the span members — the incremental-decode pruning
/// of the eq. (4)–(6) centroid pass, O(|affected|·N·D) instead of
/// O(C·N·D).
///
/// Bit-exactness: each centroid row's online-softmax sweep is
/// independent of every other centroid row (the per-row invariance the
/// worker-count determinism property enforces), so computing a subset
/// of centroid rows yields exactly the bits the full [`centroids`]-wide
/// pass would, and the scatter copies them unchanged.  Returns a
/// `(groups_span.len() × Dv)` matrix, one row per span member.
pub fn clustered_span_attention_ctx(groups_span: &[u32], cent: &Matrix,
                                    k: &Matrix, v: &Matrix, ctx: &ExecCtx)
                                    -> Matrix {
    let scale = 1.0 / (cent.cols as f32).sqrt();
    // distinct affected clusters, ascending, and cluster → sub-row map
    let mut affected: Vec<usize> =
        groups_span.iter().map(|&g| g as usize).collect();
    affected.sort_unstable();
    affected.dedup();
    let mut sub_row = vec![usize::MAX; cent.rows];
    let mut cent_sub = Matrix::zeros(affected.len(), cent.cols);
    for (r, &c) in affected.iter().enumerate() {
        sub_row[c] = r;
        cent_sub.row_mut(r).copy_from_slice(cent.row(c));
    }
    let v_c = streaming_softmax_attention(&cent_sub, k, v, scale, ctx);
    let mut out = Matrix::zeros(groups_span.len(), v.cols);
    for (i, &g) in groups_span.iter().enumerate() {
        out.row_mut(i).copy_from_slice(v_c.row(sub_row[g as usize]));
    }
    out
}

/// Clustered attention kernel: LSH → Hamming K-Means → centroid attention.
#[derive(Debug, Clone, Copy)]
pub struct ClusteredAttention {
    pub clusters: usize,
    pub bits: usize,
    pub iters: usize,
}

impl AttentionKernel for ClusteredAttention {
    fn name(&self) -> String {
        format!("clustered-{}", self.clusters)
    }

    /// Masking = solving the valid-prefix sub-problem: LSH hashes and
    /// K-Means assigns only the valid queries (padded rows never vote
    /// or form centroids), the centroid pass sweeps only valid keys,
    /// and the RNG draws (the projection directions) depend only on
    /// the head dim — so the masked run is bit-identical to the
    /// unpadded run.
    ///
    /// A `query_span` still clusters *every* valid query (the joint
    /// assignment is what the span rows' outputs depend on — and the
    /// RNG draws stay identical to the spanless solve), but then runs
    /// the centroid attention pass only for the clusters the span
    /// touches ([`clustered_span_attention_ctx`]): exact span bits at
    /// O(|affected|·N·D) instead of O(C·N·D).
    fn solve(&self, p: &AttnProblem<'_>, rng: &mut Xoshiro256,
             ctx: &ExecCtx) -> Matrix {
        assert!(!p.causal, "clustered does not support causal attention");
        let (q, k, v) = p.valid_qkv();
        let cl = clustering::cluster_queries_ctx(
            &q, self.clusters, self.bits, self.iters, rng, ctx);
        if p.is_spanned() {
            let cent = centroids(&q, &cl);
            let span = clustered_span_attention_ctx(
                &cl.groups[p.span_start()..], &cent, &k, &v, ctx);
            return p.restore_span(span);
        }
        p.restore_rows(clustered_attention_ctx(&q, &k, &v, &cl, ctx))
    }

    fn cost(&self, n: usize, dk: usize, dv: usize) -> Cost {
        let (n64, dk64, dv64) = (n as u64, dk as u64, dv as u64);
        let (c, b, l) = (self.clusters as u64, self.bits as u64,
                         self.iters as u64);
        Cost {
            // LSH + Lloyd (O(NCL + ND_kB)) + streaming centroid attention
            flops: n64 * dk64 * b + n64 * c * l
                + c * n64 * (dk64 + dv64),
            // packed K + bit codes + the (C × Dv) centroid values; the
            // (C × N) matrix is no longer materialised on the value path
            bytes: 4 * (n64 * dk64 + c * dv64) + n64 * b / 8,
        }
    }
}
