//! ct-contract: bit-exact
//!
//! Request descriptors for the attention engine: [`AttnProblem`] (one
//! slice) and [`AttnBatch`] (a (B, H, N, D) workload), the structs every
//! kernel entry point now takes instead of growing positional argument
//! lists.
//!
//! The descriptor is where per-request options travel — the
//! valid-length mask, the incremental-decode query span, and the
//! KV-cache handles ([`CacheRef`] / [`SessionRef`]) — without touching
//! a single kernel signature.
//!
//! ## Valid-length masking
//!
//! Serving pads variable-length requests up to a static bucket length,
//! and the padded rows must not leak into the math: a padded K row
//! scoring `q·0 = 0` still soaks up softmax mass.  `valid_len` (per
//! slice) / `lens` (per sequence) declare how many *leading* rows are
//! real.  The masking contract every kernel obeys:
//!
//! > Solving a bucket-padded problem with `valid_len = l` is
//! > **bit-for-bit identical** to solving the unpadded `l`-row problem;
//! > output rows `l..` are exactly zero.
//!
//! The mechanism is the valid-prefix view ([`Matrix::row_prefix`],
//! [`BatchMatrix::slice_valid`]): padding always sits *after* the valid
//! rows, rows are contiguous in row-major storage, so the valid prefix
//! of a padded tensor *is* the unpadded tensor.  Kernels solve that
//! sub-problem — streaming softmax sweeps only valid key blocks,
//! clustering hashes and assigns only valid queries, top-k can never
//! select a padded key — and zero-extend the output.  Nothing about the
//! contract is approximate, and `proptest/attention_props.rs` enforces
//! it for every kernel family at multiple worker counts.
//!
//! ## Incremental query spans
//!
//! Autoregressive decode re-attends the *new* query rows over the full
//! key history; recomputing the prefix rows every step is the O(N²)
//! waste the KV cache exists to remove.  `query_span = Some(s)`
//! declares that only query rows `s..valid` need computing this step.
//! The span contract (enforced per family alongside the masking
//! property):
//!
//! > Solving with `query_span = s` yields output rows `s..valid` that
//! > are **bit-for-bit identical** to rows `s..valid` of the same
//! > solve without a span; rows outside the span are exactly zero.
//!
//! Keys/values are *not* restricted — the span rows attend over every
//! valid key.  Row-independent kernels (full, shared-full, oracle-top)
//! genuinely compute only the span (O(m·N) instead of O(N²)); kernels
//! whose rows couple through joint state (clustered query assignments,
//! LSH bucket sorts) may compute more internally but must emit the
//! identical span bits.  The span requires a self-shaped problem
//! (`q.rows == k.rows`, the serving layout), like masking.
//!
//! ## Causal masking
//!
//! `causal = true` declares autoregressive attention: query row `i`
//! attends keys `0..=i` only (its own prefix, self included).  The
//! descriptors were bidirectional-only before the linear family landed;
//! causality is a *kernel capability*, not a universal contract — only
//! kernels whose [`AttentionKernel::supports_causal`] returns `true`
//! accept a causal descriptor (the rest assert), and execution entry
//! points reject causal batches for non-supporting kernels up front.
//! Causal composes with the other options: masking restricts the key
//! prefix to the valid rows, a `query_span` restricts which rows are
//! emitted (each span row still attends exactly its own key prefix),
//! and the span contract holds verbatim — causal span rows are
//! bit-identical to the same rows of the spanless causal solve.  Like
//! masking, causal needs a self-shaped problem (`q.rows == k.rows`).

use std::borrow::Cow;

use crate::tensor::batch::BatchMatrix;
use crate::tensor::Matrix;

/// Handle to one decode session's KV-cache entry: the session id plus a
/// generation counter.
///
/// The generation exists so a stale handle can never alias fresh state:
/// a cache entry stored under generation `g` is invisible to a lookup
/// carrying any other generation (the lookup misses and the entry is
/// replaced).  Gateways bump the generation whenever a session id is
/// (re-)created, so a client resurrecting an old id gets a clean miss
/// instead of someone else's keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheRef {
    /// Session id (client-scoped).
    pub session: u64,
    /// Generation of the session id — mismatches always miss.
    pub generation: u64,
}

/// Per-sequence incremental-decode annotation on an [`AttnBatch`]: the
/// cache handle plus where this step's new rows start.
///
/// `span_start` is the length of the history the cache is expected to
/// hold; rows `span_start..lens[b]` of the sequence are this step's new
/// tokens.  A caching backend that finds the cached prefix (same
/// session, same generation, cached length == `span_start`) appends
/// only the new K/V rows and solves only the span; any mismatch —
/// evicted entry, stale generation, desynced length — falls back to a
/// full recompute of the sequence and repopulates the cache, which is
/// bit-identical by the span contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionRef {
    pub cache: CacheRef,
    /// First new query row of this step (0 = full prefill).
    pub span_start: usize,
}

/// One attention request slice: Q/K/V plus the request options.
///
/// `q`, `k`: (N × Dk), `v`: (N × Dv).  With `valid_len = Some(l)` only
/// the leading `l` rows are real (bucket padding fills the tail) and the
/// kernel must honor the masking contract (module docs).  `None` means
/// every row is valid — the dense case.
///
/// ```
/// use clustered_transformers::attention::{kernel_by_name, AttnProblem};
/// use clustered_transformers::exec::ExecCtx;
/// use clustered_transformers::prng::Xoshiro256;
/// use clustered_transformers::tensor::Matrix;
///
/// let mut rng = Xoshiro256::new(0);
/// let (q, k, v) = (Matrix::randn(8, 4, &mut rng),
///                  Matrix::randn(8, 4, &mut rng),
///                  Matrix::randn(8, 4, &mut rng));
/// let kernel = kernel_by_name("full").unwrap();
/// // rows 5.. are bucket padding: mask them
/// let p = AttnProblem::new(&q, &k, &v).with_valid_len(5);
/// let mut r = Xoshiro256::new(1);
/// let out = kernel.solve(&p, &mut r, &ExecCtx::sequential());
/// assert_eq!((out.rows, out.cols), (8, 4));
/// assert!(out.data[5 * 4..].iter().all(|&x| x == 0.0));
/// ```
#[derive(Debug, Clone, Copy)]
pub struct AttnProblem<'a> {
    pub q: &'a Matrix,
    pub k: &'a Matrix,
    pub v: &'a Matrix,
    /// Leading rows that are real; `None` = all of them.
    pub valid_len: Option<usize>,
    /// First query row that needs computing (incremental decode);
    /// `None` = all valid rows.  See the span contract (module docs).
    pub query_span: Option<usize>,
    /// Autoregressive masking: row `i` attends keys `0..=i` only.
    /// Kernel capability, not a universal contract (module docs).
    pub causal: bool,
}

impl<'a> AttnProblem<'a> {
    /// Dense problem: every row of `q`/`k`/`v` is valid.
    pub fn new(q: &'a Matrix, k: &'a Matrix, v: &'a Matrix) -> Self {
        let p =
            Self { q, k, v, valid_len: None, query_span: None, causal: false };
        p.validate();
        p
    }

    /// Declare that only the leading `valid_len` rows are real.
    ///
    /// Masking is defined for self-shaped problems (`q.rows == k.rows`,
    /// the serving layout) and `1 <= valid_len <= N`; a full-length
    /// `valid_len` is legal and equivalent to the dense problem.
    pub fn with_valid_len(mut self, valid_len: usize) -> Self {
        self.valid_len = Some(valid_len);
        self.validate();
        self
    }

    /// Declare that only query rows `start..valid` need computing
    /// (incremental decode); the span rows still attend over *every*
    /// valid key.  Requires a self-shaped problem and `start < valid`;
    /// `start == 0` is legal and equivalent to no span.
    pub fn with_query_span(mut self, start: usize) -> Self {
        self.query_span = Some(start);
        self.validate();
        self
    }

    /// Declare autoregressive attention: row `i` attends keys `0..=i`.
    /// Requires a self-shaped problem (`q.rows == k.rows`) and a kernel
    /// whose [`super::AttentionKernel::supports_causal`] is `true`.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self.validate();
        self
    }

    /// Total rows of the (possibly padded) problem.
    #[inline]
    pub fn rows(&self) -> usize {
        self.q.rows
    }

    /// Rows that are real.
    #[inline]
    pub fn valid(&self) -> usize {
        self.valid_len.unwrap_or(self.q.rows)
    }

    /// Does the mask actually exclude any row?
    #[inline]
    pub fn is_masked(&self) -> bool {
        self.valid_len.is_some_and(|l| l < self.q.rows)
    }

    /// First query row to compute (0 when no span is set).
    #[inline]
    pub fn span_start(&self) -> usize {
        self.query_span.unwrap_or(0)
    }

    /// Does the span actually exclude any valid row?
    #[inline]
    pub fn is_spanned(&self) -> bool {
        self.query_span.is_some_and(|s| s > 0)
    }

    /// Re-assert the constructor invariants.  Fields are public (the
    /// descriptor is the API surface), so a literally-constructed
    /// problem can bypass [`AttnProblem::new`] — execution entry points
    /// call this so malformed descriptors fail loudly instead of
    /// computing garbage.
    pub fn validate(&self) {
        assert_eq!(self.q.cols, self.k.cols, "q/k head-dim mismatch");
        assert_eq!(self.k.rows, self.v.rows, "k/v length mismatch");
        if let Some(l) = self.valid_len {
            assert_eq!(self.q.rows, self.k.rows,
                       "valid-length masking needs q/k of equal length");
            assert!((1..=self.q.rows).contains(&l),
                    "valid_len {l} out of 1..={}", self.q.rows);
        }
        if let Some(s) = self.query_span {
            assert_eq!(self.q.rows, self.k.rows,
                       "query_span needs q/k of equal length");
            assert!(s < self.valid(),
                    "query_span {s} leaves no row in 0..{}", self.valid());
        }
        if self.causal {
            assert_eq!(self.q.rows, self.k.rows,
                       "causal attention needs q/k of equal length");
        }
    }

    /// The valid-prefix sub-problem — borrowed when nothing is masked,
    /// owned `row_prefix` copies when it is.  Kernels solve exactly
    /// this (it validates the descriptor first), which is what makes
    /// the masked run bit-identical to the unpadded run.
    pub fn valid_qkv(&self)
                     -> (Cow<'a, Matrix>, Cow<'a, Matrix>, Cow<'a, Matrix>) {
        self.validate();
        match self.valid_len {
            Some(l) if l < self.q.rows => (
                Cow::Owned(self.q.row_prefix(l)),
                Cow::Owned(self.k.row_prefix(l)),
                Cow::Owned(self.v.row_prefix(l)),
            ),
            _ => (Cow::Borrowed(self.q), Cow::Borrowed(self.k),
                  Cow::Borrowed(self.v)),
        }
    }

    /// Zero-extend a valid-rows output back to the full (padded) height
    /// — masked output rows are defined to be zero.
    pub fn restore_rows(&self, valid_out: Matrix) -> Matrix {
        if !self.is_masked() {
            return valid_out;
        }
        debug_assert_eq!(valid_out.rows, self.valid());
        let mut out = Matrix::zeros(self.rows(), valid_out.cols);
        out.data[..valid_out.data.len()].copy_from_slice(&valid_out.data);
        out
    }

    /// The active query rows of this step (rows `span_start..valid`),
    /// borrowed when the whole problem is active.  Row-independent
    /// kernels solve exactly these rows against the valid keys, which
    /// is what makes incremental decode O(m·N) instead of O(N²).
    pub fn span_q(&self) -> Cow<'a, Matrix> {
        self.validate();
        let (s, l) = (self.span_start(), self.valid());
        if s == 0 && l == self.q.rows {
            Cow::Borrowed(self.q)
        } else {
            Cow::Owned(self.q.row_span(s, l))
        }
    }

    /// Embed a span-rows output (`valid - span_start` rows) back at the
    /// span offset of the full (padded) height; every row outside the
    /// span — the skipped prefix and the padding — is defined to be
    /// zero.  With no span this is exactly [`AttnProblem::restore_rows`].
    pub fn restore_span(&self, span_out: Matrix) -> Matrix {
        let s = self.span_start();
        if s == 0 {
            return self.restore_rows(span_out);
        }
        debug_assert_eq!(span_out.rows, self.valid() - s);
        let mut out = Matrix::zeros(self.rows(), span_out.cols);
        let off = s * span_out.cols;
        out.data[off..off + span_out.data.len()]
            .copy_from_slice(&span_out.data);
        out
    }
}

/// A batched multi-head attention request: (B, H, N, D) tensors, the
/// base PRNG seed, and optional per-*sequence* valid lengths.
///
/// `lens[b]` masks every head of sequence `b` (heads share a length);
/// `None` means every row of every slice is valid.  Seeding is part of
/// the descriptor because output slice `s = b·H + h` must be a pure
/// function of `(inputs[s], seed, s)` — the batched determinism
/// contract.
#[derive(Debug, Clone, Copy)]
pub struct AttnBatch<'a> {
    pub q: &'a BatchMatrix,
    pub k: &'a BatchMatrix,
    pub v: &'a BatchMatrix,
    /// Base seed of the per-slice PRNG streams (`prng::slice_stream`).
    pub seed: u64,
    /// Per-sequence valid lengths (`len == q.batch`); `None` = dense.
    pub lens: Option<&'a [usize]>,
    /// Per-sequence decode-session annotations (`len == q.batch`);
    /// `None` = no sequence is a session step.  Consumed by caching
    /// backends ([`crate::attention::CachingBackend`]); plain kernels
    /// ignore it (they compute every valid row), which is always
    /// correct because only rows `span_start..` of a session sequence
    /// are contractual.  A sequence with `Some(sref)` draws its PRNG
    /// streams from the session (`prng::session_seed`), not its batch
    /// slot, so its output is invariant to co-batching.
    pub sessions: Option<&'a [Option<SessionRef>]>,
    /// Autoregressive masking for every sequence of the batch: row `i`
    /// attends keys `0..=i` of its own sequence.  Kernel capability —
    /// see the module docs and [`AttnProblem::causal`].
    pub causal: bool,
}

impl<'a> AttnBatch<'a> {
    /// Dense batch: every row of every slice is valid.
    pub fn new(q: &'a BatchMatrix, k: &'a BatchMatrix, v: &'a BatchMatrix,
               seed: u64) -> Self {
        let b = Self { q, k, v, seed, lens: None, sessions: None,
                       causal: false };
        b.validate();
        b
    }

    /// Attach per-sequence valid lengths (each in `1..=N`).
    pub fn with_lens(mut self, lens: &'a [usize]) -> Self {
        self.lens = Some(lens);
        self.validate();
        self
    }

    /// Attach per-sequence decode-session annotations (one entry per
    /// sequence; `None` entries are ordinary one-shot requests).
    pub fn with_sessions(mut self,
                         sessions: &'a [Option<SessionRef>]) -> Self {
        self.sessions = Some(sessions);
        self.validate();
        self
    }

    /// Declare every sequence autoregressive (row `i` attends keys
    /// `0..=i`).  Execution entry points reject causal batches for
    /// kernels that don't support causality.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self.validate();
        self
    }

    /// Re-assert the constructor invariants (the descriptor's public
    /// fields can bypass [`AttnBatch::new`] / [`AttnBatch::with_lens`];
    /// `solve_batch` and `solve_batch_seq` call this so malformed
    /// descriptors fail loudly at the execution boundary).
    pub fn validate(&self) {
        assert_eq!((self.q.batch, self.q.heads),
                   (self.k.batch, self.k.heads), "q/k batch-head mismatch");
        assert_eq!((self.q.batch, self.q.heads),
                   (self.v.batch, self.v.heads), "q/v batch-head mismatch");
        assert_eq!(self.q.cols, self.k.cols, "q/k head-dim mismatch");
        assert_eq!(self.q.rows, self.k.rows, "q/k length mismatch");
        assert_eq!(self.k.rows, self.v.rows, "k/v length mismatch");
        if let Some(lens) = self.lens {
            assert_eq!(lens.len(), self.q.batch,
                       "lens must have one entry per sequence");
            for (b, &l) in lens.iter().enumerate() {
                assert!((1..=self.q.rows).contains(&l),
                        "lens[{b}] = {l} out of 1..={}", self.q.rows);
            }
        }
        if let Some(sessions) = self.sessions {
            assert_eq!(sessions.len(), self.q.batch,
                       "sessions must have one entry per sequence");
            for (b, s) in sessions.iter().enumerate() {
                if let Some(sref) = s {
                    let l = self.valid_len(b);
                    assert!(sref.span_start < l,
                            "sessions[{b}] span_start {} leaves no row \
                             in 0..{l}", sref.span_start);
                }
            }
        }
    }

    /// Valid rows of sequence `b`.
    #[inline]
    pub fn valid_len(&self, b: usize) -> usize {
        self.lens.map_or(self.q.rows, |l| l[b])
    }

    /// Valid rows of flat slice `s = b·H + h` (heads share the
    /// sequence's length).
    #[inline]
    pub fn slice_valid_len(&self, s: usize) -> usize {
        self.valid_len(s / self.q.heads)
    }

    /// Does any sequence mask any row?
    pub fn is_masked(&self) -> bool {
        self.lens
            .is_some_and(|ls| ls.iter().any(|&l| l < self.q.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256;

    fn qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Xoshiro256::new(seed);
        (Matrix::randn(n, d, &mut rng), Matrix::randn(n, d, &mut rng),
         Matrix::randn(n, d, &mut rng))
    }

    #[test]
    fn dense_problem_borrows_and_masked_problem_copies_the_prefix() {
        let (q, k, v) = qkv(8, 4, 1);
        let dense = AttnProblem::new(&q, &k, &v);
        assert!(!dense.is_masked());
        assert_eq!((dense.rows(), dense.valid()), (8, 8));
        let (dq, _, _) = dense.valid_qkv();
        assert!(matches!(dq, Cow::Borrowed(_)));

        let masked = AttnProblem::new(&q, &k, &v).with_valid_len(5);
        assert!(masked.is_masked());
        assert_eq!((masked.rows(), masked.valid()), (8, 5));
        let (mq, mk, mv) = masked.valid_qkv();
        assert!(mq.bit_identical(&q.row_prefix(5)));
        assert!(mk.bit_identical(&k.row_prefix(5)));
        assert!(mv.bit_identical(&v.row_prefix(5)));

        // full-length valid_len is the dense problem
        let full = AttnProblem::new(&q, &k, &v).with_valid_len(8);
        assert!(!full.is_masked());
        let (fq, _, _) = full.valid_qkv();
        assert!(matches!(fq, Cow::Borrowed(_)));
    }

    #[test]
    fn restore_rows_zero_extends_masked_output() {
        let (q, k, v) = qkv(6, 3, 2);
        let p = AttnProblem::new(&q, &k, &v).with_valid_len(2);
        let got = p.restore_rows(Matrix::from_vec(2, 3,
                                                  vec![1., 2., 3., 4., 5.,
                                                       6.]));
        assert_eq!((got.rows, got.cols), (6, 3));
        assert_eq!(&got.data[..6], &[1., 2., 3., 4., 5., 6.]);
        assert!(got.data[6..].iter().all(|&x| x == 0.0));
        // dense problems pass through untouched
        let dense = AttnProblem::new(&q, &k, &v);
        let m = Matrix::from_vec(6, 3, (0..18).map(|x| x as f32).collect());
        assert!(dense.restore_rows(m.clone()).bit_identical(&m));
    }

    #[test]
    fn query_span_selects_the_tail_and_restores_at_offset() {
        let (q, k, v) = qkv(8, 4, 9);
        // span over the masked valid prefix: rows 5..7 are active
        let p = AttnProblem::new(&q, &k, &v)
            .with_valid_len(7)
            .with_query_span(5);
        assert!(p.is_spanned());
        assert_eq!(p.span_start(), 5);
        let sq = p.span_q();
        assert!(sq.bit_identical(&q.row_span(5, 7)));
        // restore: 2 active rows land at offset 5, everything else zero
        let out = p.restore_span(Matrix::from_vec(2, 4, vec![1.0; 8]));
        assert_eq!((out.rows, out.cols), (8, 4));
        assert!(out.data[..5 * 4].iter().all(|&x| x == 0.0));
        assert!(out.data[5 * 4..7 * 4].iter().all(|&x| x == 1.0));
        assert!(out.data[7 * 4..].iter().all(|&x| x == 0.0));
        // span 0 is the dense problem: borrow, no copy
        let dense = AttnProblem::new(&q, &k, &v).with_query_span(0);
        assert!(!dense.is_spanned());
        assert!(matches!(dense.span_q(), Cow::Borrowed(_)));
    }

    #[test]
    #[should_panic(expected = "query_span")]
    fn query_span_past_the_valid_rows_is_rejected() {
        let (q, k, v) = qkv(8, 4, 10);
        let _ = AttnProblem::new(&q, &k, &v)
            .with_valid_len(5)
            .with_query_span(5); // leaves no active row
    }

    #[test]
    fn causal_flag_travels_and_composes_with_mask_and_span() {
        let (q, k, v) = qkv(8, 4, 11);
        let p = AttnProblem::new(&q, &k, &v)
            .with_valid_len(6)
            .with_query_span(4)
            .with_causal(true);
        assert!(p.causal && p.is_masked() && p.is_spanned());
        // with_causal(false) is the bidirectional default
        assert!(!AttnProblem::new(&q, &k, &v).with_causal(false).causal);
        let mut rng = Xoshiro256::new(12);
        let bq = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let bk = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let bv = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let b = AttnBatch::new(&bq, &bk, &bv, 3).with_causal(true);
        assert!(b.causal);
    }

    #[test]
    #[should_panic(expected = "causal")]
    fn causal_rejects_cross_shaped_problems() {
        let mut rng = Xoshiro256::new(13);
        let q = Matrix::randn(4, 2, &mut rng);
        let k = Matrix::randn(6, 2, &mut rng); // q.rows != k.rows
        let v = Matrix::randn(6, 2, &mut rng);
        let _ = AttnProblem::new(&q, &k, &v).with_causal(true);
    }

    #[test]
    fn cache_refs_compare_by_session_and_generation() {
        let a = CacheRef { session: 1, generation: 0 };
        let b = CacheRef { session: 1, generation: 1 };
        assert_ne!(a, b);
        assert_eq!(a, CacheRef { session: 1, generation: 0 });
        let s = SessionRef { cache: a, span_start: 16 };
        assert_eq!(s.cache.session, 1);
    }

    #[test]
    #[should_panic(expected = "valid_len")]
    fn zero_valid_len_is_rejected() {
        let (q, k, v) = qkv(4, 2, 3);
        let _ = AttnProblem::new(&q, &k, &v).with_valid_len(0);
    }

    #[test]
    #[should_panic(expected = "valid_len")]
    fn oversized_valid_len_is_rejected() {
        let (q, k, v) = qkv(4, 2, 4);
        let _ = AttnProblem::new(&q, &k, &v).with_valid_len(5);
    }

    #[test]
    fn batch_lens_resolve_per_slice_head_major() {
        let mut rng = Xoshiro256::new(5);
        let q = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let k = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let v = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let dense = AttnBatch::new(&q, &k, &v, 7);
        assert!(!dense.is_masked());
        assert_eq!(dense.slice_valid_len(5), 8);

        let lens = [3usize, 8];
        let ragged = AttnBatch::new(&q, &k, &v, 7).with_lens(&lens);
        assert!(ragged.is_masked());
        // slices 0..3 belong to sequence 0, slices 3..6 to sequence 1
        for s in 0..3 {
            assert_eq!(ragged.slice_valid_len(s), 3, "slice {s}");
        }
        for s in 3..6 {
            assert_eq!(ragged.slice_valid_len(s), 8, "slice {s}");
        }
        // all-full lens are not a mask
        let full = [8usize, 8];
        assert!(!AttnBatch::new(&q, &k, &v, 7).with_lens(&full).is_masked());
    }

    #[test]
    #[should_panic(expected = "lens")]
    fn batch_lens_length_must_match_batch() {
        let mut rng = Xoshiro256::new(6);
        let q = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let k = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let v = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let lens = [4usize];
        let _ = AttnBatch::new(&q, &k, &v, 0).with_lens(&lens);
    }

    #[test]
    fn batch_sessions_attach_per_sequence() {
        let mut rng = Xoshiro256::new(8);
        let q = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let k = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let v = BatchMatrix::randn(2, 1, 8, 4, &mut rng);
        let lens = [6usize, 8];
        let sref = SessionRef {
            cache: CacheRef { session: 9, generation: 0 },
            span_start: 4,
        };
        let sessions = [Some(sref), None];
        let b = AttnBatch::new(&q, &k, &v, 0)
            .with_lens(&lens)
            .with_sessions(&sessions);
        assert_eq!(b.sessions.unwrap()[0], Some(sref));
        assert!(b.sessions.unwrap()[1].is_none());
    }

    #[test]
    #[should_panic(expected = "span_start")]
    fn batch_session_span_must_leave_a_row() {
        let mut rng = Xoshiro256::new(9);
        let q = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let k = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let v = BatchMatrix::randn(1, 1, 8, 4, &mut rng);
        let lens = [5usize];
        let sessions = [Some(SessionRef {
            cache: CacheRef { session: 1, generation: 0 },
            span_start: 5, // == valid len: no new row
        })];
        let _ = AttnBatch::new(&q, &k, &v, 0)
            .with_lens(&lens)
            .with_sessions(&sessions);
    }

    #[test]
    #[should_panic(expected = "lens[1]")]
    fn batch_lens_entries_must_fit_the_rows() {
        let mut rng = Xoshiro256::new(7);
        let q = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let k = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let v = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let lens = [4usize, 5];
        let _ = AttnBatch::new(&q, &k, &v, 0).with_lens(&lens);
    }
}
