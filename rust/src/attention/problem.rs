//! Request descriptors for the attention engine: [`AttnProblem`] (one
//! slice) and [`AttnBatch`] (a (B, H, N, D) workload), the structs every
//! kernel entry point now takes instead of growing positional argument
//! lists.
//!
//! The descriptor is where per-request options travel — today the
//! valid-length mask, tomorrow KV-cache handles and backend hints —
//! without touching a single kernel signature again.
//!
//! ## Valid-length masking
//!
//! Serving pads variable-length requests up to a static bucket length,
//! and the padded rows must not leak into the math: a padded K row
//! scoring `q·0 = 0` still soaks up softmax mass.  `valid_len` (per
//! slice) / `lens` (per sequence) declare how many *leading* rows are
//! real.  The masking contract every kernel obeys:
//!
//! > Solving a bucket-padded problem with `valid_len = l` is
//! > **bit-for-bit identical** to solving the unpadded `l`-row problem;
//! > output rows `l..` are exactly zero.
//!
//! The mechanism is the valid-prefix view ([`Matrix::row_prefix`],
//! [`BatchMatrix::slice_valid`]): padding always sits *after* the valid
//! rows, rows are contiguous in row-major storage, so the valid prefix
//! of a padded tensor *is* the unpadded tensor.  Kernels solve that
//! sub-problem — streaming softmax sweeps only valid key blocks,
//! clustering hashes and assigns only valid queries, top-k can never
//! select a padded key — and zero-extend the output.  Nothing about the
//! contract is approximate, and `proptest/attention_props.rs` enforces
//! it for every kernel family at multiple worker counts.

use std::borrow::Cow;

use crate::tensor::batch::BatchMatrix;
use crate::tensor::Matrix;

/// One attention request slice: Q/K/V plus the request options.
///
/// `q`, `k`: (N × Dk), `v`: (N × Dv).  With `valid_len = Some(l)` only
/// the leading `l` rows are real (bucket padding fills the tail) and the
/// kernel must honor the masking contract (module docs).  `None` means
/// every row is valid — the dense case.
///
/// ```
/// use clustered_transformers::attention::{kernel_by_name, AttnProblem};
/// use clustered_transformers::exec::ExecCtx;
/// use clustered_transformers::prng::Xoshiro256;
/// use clustered_transformers::tensor::Matrix;
///
/// let mut rng = Xoshiro256::new(0);
/// let (q, k, v) = (Matrix::randn(8, 4, &mut rng),
///                  Matrix::randn(8, 4, &mut rng),
///                  Matrix::randn(8, 4, &mut rng));
/// let kernel = kernel_by_name("full").unwrap();
/// // rows 5.. are bucket padding: mask them
/// let p = AttnProblem::new(&q, &k, &v).with_valid_len(5);
/// let mut r = Xoshiro256::new(1);
/// let out = kernel.solve(&p, &mut r, &ExecCtx::sequential());
/// assert_eq!((out.rows, out.cols), (8, 4));
/// assert!(out.data[5 * 4..].iter().all(|&x| x == 0.0));
/// ```
#[derive(Debug, Clone, Copy)]
pub struct AttnProblem<'a> {
    pub q: &'a Matrix,
    pub k: &'a Matrix,
    pub v: &'a Matrix,
    /// Leading rows that are real; `None` = all of them.
    pub valid_len: Option<usize>,
}

impl<'a> AttnProblem<'a> {
    /// Dense problem: every row of `q`/`k`/`v` is valid.
    pub fn new(q: &'a Matrix, k: &'a Matrix, v: &'a Matrix) -> Self {
        let p = Self { q, k, v, valid_len: None };
        p.validate();
        p
    }

    /// Declare that only the leading `valid_len` rows are real.
    ///
    /// Masking is defined for self-shaped problems (`q.rows == k.rows`,
    /// the serving layout) and `1 <= valid_len <= N`; a full-length
    /// `valid_len` is legal and equivalent to the dense problem.
    pub fn with_valid_len(mut self, valid_len: usize) -> Self {
        self.valid_len = Some(valid_len);
        self.validate();
        self
    }

    /// Total rows of the (possibly padded) problem.
    #[inline]
    pub fn rows(&self) -> usize {
        self.q.rows
    }

    /// Rows that are real.
    #[inline]
    pub fn valid(&self) -> usize {
        self.valid_len.unwrap_or(self.q.rows)
    }

    /// Does the mask actually exclude any row?
    #[inline]
    pub fn is_masked(&self) -> bool {
        self.valid_len.is_some_and(|l| l < self.q.rows)
    }

    /// Re-assert the constructor invariants.  Fields are public (the
    /// descriptor is the API surface), so a literally-constructed
    /// problem can bypass [`AttnProblem::new`] — execution entry points
    /// call this so malformed descriptors fail loudly instead of
    /// computing garbage.
    pub fn validate(&self) {
        assert_eq!(self.q.cols, self.k.cols, "q/k head-dim mismatch");
        assert_eq!(self.k.rows, self.v.rows, "k/v length mismatch");
        if let Some(l) = self.valid_len {
            assert_eq!(self.q.rows, self.k.rows,
                       "valid-length masking needs q/k of equal length");
            assert!((1..=self.q.rows).contains(&l),
                    "valid_len {l} out of 1..={}", self.q.rows);
        }
    }

    /// The valid-prefix sub-problem — borrowed when nothing is masked,
    /// owned `row_prefix` copies when it is.  Kernels solve exactly
    /// this (it validates the descriptor first), which is what makes
    /// the masked run bit-identical to the unpadded run.
    pub fn valid_qkv(&self)
                     -> (Cow<'a, Matrix>, Cow<'a, Matrix>, Cow<'a, Matrix>) {
        self.validate();
        match self.valid_len {
            Some(l) if l < self.q.rows => (
                Cow::Owned(self.q.row_prefix(l)),
                Cow::Owned(self.k.row_prefix(l)),
                Cow::Owned(self.v.row_prefix(l)),
            ),
            _ => (Cow::Borrowed(self.q), Cow::Borrowed(self.k),
                  Cow::Borrowed(self.v)),
        }
    }

    /// Zero-extend a valid-rows output back to the full (padded) height
    /// — masked output rows are defined to be zero.
    pub fn restore_rows(&self, valid_out: Matrix) -> Matrix {
        if !self.is_masked() {
            return valid_out;
        }
        debug_assert_eq!(valid_out.rows, self.valid());
        let mut out = Matrix::zeros(self.rows(), valid_out.cols);
        out.data[..valid_out.data.len()].copy_from_slice(&valid_out.data);
        out
    }
}

/// A batched multi-head attention request: (B, H, N, D) tensors, the
/// base PRNG seed, and optional per-*sequence* valid lengths.
///
/// `lens[b]` masks every head of sequence `b` (heads share a length);
/// `None` means every row of every slice is valid.  Seeding is part of
/// the descriptor because output slice `s = b·H + h` must be a pure
/// function of `(inputs[s], seed, s)` — the batched determinism
/// contract.
#[derive(Debug, Clone, Copy)]
pub struct AttnBatch<'a> {
    pub q: &'a BatchMatrix,
    pub k: &'a BatchMatrix,
    pub v: &'a BatchMatrix,
    /// Base seed of the per-slice PRNG streams (`prng::slice_stream`).
    pub seed: u64,
    /// Per-sequence valid lengths (`len == q.batch`); `None` = dense.
    pub lens: Option<&'a [usize]>,
}

impl<'a> AttnBatch<'a> {
    /// Dense batch: every row of every slice is valid.
    pub fn new(q: &'a BatchMatrix, k: &'a BatchMatrix, v: &'a BatchMatrix,
               seed: u64) -> Self {
        let b = Self { q, k, v, seed, lens: None };
        b.validate();
        b
    }

    /// Attach per-sequence valid lengths (each in `1..=N`).
    pub fn with_lens(mut self, lens: &'a [usize]) -> Self {
        self.lens = Some(lens);
        self.validate();
        self
    }

    /// Re-assert the constructor invariants (the descriptor's public
    /// fields can bypass [`AttnBatch::new`] / [`AttnBatch::with_lens`];
    /// `solve_batch` and `solve_batch_seq` call this so malformed
    /// descriptors fail loudly at the execution boundary).
    pub fn validate(&self) {
        assert_eq!((self.q.batch, self.q.heads),
                   (self.k.batch, self.k.heads), "q/k batch-head mismatch");
        assert_eq!((self.q.batch, self.q.heads),
                   (self.v.batch, self.v.heads), "q/v batch-head mismatch");
        assert_eq!(self.q.cols, self.k.cols, "q/k head-dim mismatch");
        assert_eq!(self.q.rows, self.k.rows, "q/k length mismatch");
        assert_eq!(self.k.rows, self.v.rows, "k/v length mismatch");
        if let Some(lens) = self.lens {
            assert_eq!(lens.len(), self.q.batch,
                       "lens must have one entry per sequence");
            for (b, &l) in lens.iter().enumerate() {
                assert!((1..=self.q.rows).contains(&l),
                        "lens[{b}] = {l} out of 1..={}", self.q.rows);
            }
        }
    }

    /// Valid rows of sequence `b`.
    #[inline]
    pub fn valid_len(&self, b: usize) -> usize {
        self.lens.map_or(self.q.rows, |l| l[b])
    }

    /// Valid rows of flat slice `s = b·H + h` (heads share the
    /// sequence's length).
    #[inline]
    pub fn slice_valid_len(&self, s: usize) -> usize {
        self.valid_len(s / self.q.heads)
    }

    /// Does any sequence mask any row?
    pub fn is_masked(&self) -> bool {
        self.lens
            .is_some_and(|ls| ls.iter().any(|&l| l < self.q.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256;

    fn qkv(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Xoshiro256::new(seed);
        (Matrix::randn(n, d, &mut rng), Matrix::randn(n, d, &mut rng),
         Matrix::randn(n, d, &mut rng))
    }

    #[test]
    fn dense_problem_borrows_and_masked_problem_copies_the_prefix() {
        let (q, k, v) = qkv(8, 4, 1);
        let dense = AttnProblem::new(&q, &k, &v);
        assert!(!dense.is_masked());
        assert_eq!((dense.rows(), dense.valid()), (8, 8));
        let (dq, _, _) = dense.valid_qkv();
        assert!(matches!(dq, Cow::Borrowed(_)));

        let masked = AttnProblem::new(&q, &k, &v).with_valid_len(5);
        assert!(masked.is_masked());
        assert_eq!((masked.rows(), masked.valid()), (8, 5));
        let (mq, mk, mv) = masked.valid_qkv();
        assert!(mq.bit_identical(&q.row_prefix(5)));
        assert!(mk.bit_identical(&k.row_prefix(5)));
        assert!(mv.bit_identical(&v.row_prefix(5)));

        // full-length valid_len is the dense problem
        let full = AttnProblem::new(&q, &k, &v).with_valid_len(8);
        assert!(!full.is_masked());
        let (fq, _, _) = full.valid_qkv();
        assert!(matches!(fq, Cow::Borrowed(_)));
    }

    #[test]
    fn restore_rows_zero_extends_masked_output() {
        let (q, k, v) = qkv(6, 3, 2);
        let p = AttnProblem::new(&q, &k, &v).with_valid_len(2);
        let got = p.restore_rows(Matrix::from_vec(2, 3,
                                                  vec![1., 2., 3., 4., 5.,
                                                       6.]));
        assert_eq!((got.rows, got.cols), (6, 3));
        assert_eq!(&got.data[..6], &[1., 2., 3., 4., 5., 6.]);
        assert!(got.data[6..].iter().all(|&x| x == 0.0));
        // dense problems pass through untouched
        let dense = AttnProblem::new(&q, &k, &v);
        let m = Matrix::from_vec(6, 3, (0..18).map(|x| x as f32).collect());
        assert!(dense.restore_rows(m.clone()).bit_identical(&m));
    }

    #[test]
    #[should_panic(expected = "valid_len")]
    fn zero_valid_len_is_rejected() {
        let (q, k, v) = qkv(4, 2, 3);
        let _ = AttnProblem::new(&q, &k, &v).with_valid_len(0);
    }

    #[test]
    #[should_panic(expected = "valid_len")]
    fn oversized_valid_len_is_rejected() {
        let (q, k, v) = qkv(4, 2, 4);
        let _ = AttnProblem::new(&q, &k, &v).with_valid_len(5);
    }

    #[test]
    fn batch_lens_resolve_per_slice_head_major() {
        let mut rng = Xoshiro256::new(5);
        let q = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let k = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let v = BatchMatrix::randn(2, 3, 8, 4, &mut rng);
        let dense = AttnBatch::new(&q, &k, &v, 7);
        assert!(!dense.is_masked());
        assert_eq!(dense.slice_valid_len(5), 8);

        let lens = [3usize, 8];
        let ragged = AttnBatch::new(&q, &k, &v, 7).with_lens(&lens);
        assert!(ragged.is_masked());
        // slices 0..3 belong to sequence 0, slices 3..6 to sequence 1
        for s in 0..3 {
            assert_eq!(ragged.slice_valid_len(s), 3, "slice {s}");
        }
        for s in 3..6 {
            assert_eq!(ragged.slice_valid_len(s), 8, "slice {s}");
        }
        // all-full lens are not a mask
        let full = [8usize, 8];
        assert!(!AttnBatch::new(&q, &k, &v, 7).with_lens(&full).is_masked());
    }

    #[test]
    #[should_panic(expected = "lens")]
    fn batch_lens_length_must_match_batch() {
        let mut rng = Xoshiro256::new(6);
        let q = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let k = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let v = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let lens = [4usize];
        let _ = AttnBatch::new(&q, &k, &v, 0).with_lens(&lens);
    }

    #[test]
    #[should_panic(expected = "lens[1]")]
    fn batch_lens_entries_must_fit_the_rows() {
        let mut rng = Xoshiro256::new(7);
        let q = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let k = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let v = BatchMatrix::randn(2, 1, 4, 2, &mut rng);
        let lens = [4usize, 5];
        let _ = AttnBatch::new(&q, &k, &v, 0).with_lens(&lens);
    }
}
